//! Evaluation cache: measurement trials in the verification environment
//! are expensive (compile + run + power capture), so each distinct pattern
//! is measured once *within a search* — re-visited genomes reuse the
//! stored fitness. The cache also doubles as the search log (every
//! pattern ever measured).
//!
//! This is the engine-local half of a two-level scheme: cross-job and
//! cross-invocation deduplication of the underlying verification trials
//! lives in the shared, thread-safe
//! [`crate::util::measure_cache::MeasureCache`] the fleet coordinator
//! attaches to each job's environment (DESIGN.md §7).

use super::genome::Genome;
use std::collections::HashMap;

/// Pattern → fitness cache with hit statistics.
#[derive(Debug, Default)]
pub struct EvalCache {
    map: HashMap<Vec<bool>, f64>,
    hits: u64,
    misses: u64,
}

impl EvalCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Is the pattern already measured?
    pub fn contains(&self, g: &Genome) -> bool {
        self.map.contains_key(&g.bits)
    }

    /// Store a measured value directly (batch evaluation path). Counts as
    /// a miss — a real measurement happened.
    pub fn insert(&mut self, g: &Genome, value: f64) {
        self.misses += 1;
        self.map.insert(g.bits.clone(), value);
    }

    /// Look up or compute-and-store the fitness of `g`.
    pub fn get_or_eval(&mut self, g: &Genome, eval: impl FnOnce(&Genome) -> f64) -> f64 {
        if let Some(&v) = self.map.get(&g.bits) {
            self.hits += 1;
            return v;
        }
        self.misses += 1;
        let v = eval(g);
        self.map.insert(g.bits.clone(), v);
        v
    }

    /// Number of distinct patterns measured.
    pub fn distinct(&self) -> usize {
        self.map.len()
    }

    /// Cache hits (re-visited patterns — measurements *saved*).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cache misses (actual measurements run).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// All measured `(pattern, value)` pairs (the search log).
    pub fn entries(&self) -> impl Iterator<Item = (Genome, f64)> + '_ {
        self.map.iter().map(|(bits, &v)| {
            (
                Genome {
                    bits: bits.clone(),
                },
                v,
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_lookup_hits() {
        let mut c = EvalCache::new();
        let g = Genome::zeros(4);
        let mut calls = 0;
        let v1 = c.get_or_eval(&g, |_| {
            calls += 1;
            0.7
        });
        let v2 = c.get_or_eval(&g, |_| {
            calls += 1;
            0.9 // would differ — must not be called
        });
        assert_eq!(v1, 0.7);
        assert_eq!(v2, 0.7);
        assert_eq!(calls, 1);
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.distinct(), 1);
    }

    #[test]
    fn distinct_patterns_both_evaluated() {
        let mut c = EvalCache::new();
        c.get_or_eval(&Genome::zeros(3), |_| 0.1);
        c.get_or_eval(&Genome::single(3, 1), |_| 0.2);
        assert_eq!(c.distinct(), 2);
        let values: Vec<f64> = c.entries().map(|(_, v)| v).collect();
        assert_eq!(values.len(), 2);
    }
}
