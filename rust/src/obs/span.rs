//! Thread-aware RAII spans plus virtual-time spans for the sched
//! simulation (the B/E and X halves of the Chrome trace).
//!
//! Wall spans ([`span`] / [`span_with`]) record paired `Begin`/`End`
//! events against a process-epoch monotonic clock under [`PID_WALL`];
//! each OS thread gets a small stable integer lane. Virtual spans
//! ([`virtual_span`]) are emitted as single `Complete` events with
//! simulated-clock timestamps under [`PID_VIRTUAL`] — one lane per
//! cluster node — so the sched half of a trace is deterministic per
//! seed regardless of host timing.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Chrome-trace process lane for wall-clock spans.
pub const PID_WALL: u32 = 1;
/// Chrome-trace process lane for virtual (simulated-time) spans.
pub const PID_VIRTUAL: u32 = 2;

/// Event phase, mirroring the Chrome trace-event `ph` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Duration-begin (`ph:"B"`), paired with a later [`Phase::End`].
    Begin,
    /// Duration-end (`ph:"E"`).
    End,
    /// Complete event (`ph:"X"`) carrying its own duration in µs.
    Complete {
        /// Span duration in microseconds.
        dur_us: u64,
    },
}

/// One recorded trace event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Begin / End / Complete.
    pub phase: Phase,
    /// Span name; `None` on `End` events (Chrome infers it from the
    /// matching `Begin`).
    pub name: Option<String>,
    /// Static category string (e.g. `"pipeline"`, `"sched"`).
    pub cat: &'static str,
    /// Timestamp in microseconds (wall: since process epoch; virtual:
    /// since simulation start).
    pub ts_us: u64,
    /// Process lane ([`PID_WALL`] or [`PID_VIRTUAL`]).
    pub pid: u32,
    /// Thread lane (wall: per-OS-thread counter; virtual: node index).
    pub tid: u32,
}

static EVENTS: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU32 = AtomicU32::new(1);

thread_local! {
    static TID: u32 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

fn push(ev: Event) {
    EVENTS.lock().unwrap_or_else(|e| e.into_inner()).push(ev);
}

/// RAII guard returned by [`span`]; records the `End` event on drop.
/// When spans are disabled the guard is inert (no event on drop).
#[must_use = "a span guard records its End event when dropped"]
pub struct SpanGuard {
    live: bool,
    cat: &'static str,
    tid: u32,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.live {
            push(Event {
                phase: Phase::End,
                name: None,
                cat: self.cat,
                ts_us: now_us(),
                pid: PID_WALL,
                tid: self.tid,
            });
        }
    }
}

/// Open a wall-clock span. Disabled path: one relaxed load, no
/// allocation (the `&str` is only copied when recording).
#[inline]
pub fn span(cat: &'static str, name: &str) -> SpanGuard {
    if !super::enabled(super::SPANS) {
        return SpanGuard {
            live: false,
            cat,
            tid: 0,
        };
    }
    span_record(cat, name.to_string())
}

/// Open a wall-clock span with a lazily built name: the closure runs
/// only when spans are enabled, so call sites pay no formatting cost
/// on the disabled path.
#[inline]
pub fn span_with<F: FnOnce() -> String>(cat: &'static str, name_fn: F) -> SpanGuard {
    if !super::enabled(super::SPANS) {
        return SpanGuard {
            live: false,
            cat,
            tid: 0,
        };
    }
    span_record(cat, name_fn())
}

fn span_record(cat: &'static str, name: String) -> SpanGuard {
    let tid = TID.with(|t| *t);
    push(Event {
        phase: Phase::Begin,
        name: Some(name),
        cat,
        ts_us: now_us(),
        pid: PID_WALL,
        tid,
    });
    SpanGuard {
        live: true,
        cat,
        tid,
    }
}

/// Record a virtual-time span (`ph:"X"`) under [`PID_VIRTUAL`], with
/// simulated-clock endpoints in seconds and one thread lane per
/// cluster node. Timestamps are `round()`ed to whole microseconds so
/// the emitted trace is a pure function of the deterministic f64
/// schedule, not of host timing.
#[inline]
pub fn virtual_span(cat: &'static str, name_fn: impl FnOnce() -> String, lane: u32, start_s: f64, end_s: f64) {
    if !super::enabled(super::SPANS) {
        return;
    }
    let ts_us = (start_s * 1e6).round().max(0.0) as u64;
    let end_us = (end_s * 1e6).round().max(0.0) as u64;
    push(Event {
        phase: Phase::Complete {
            dur_us: end_us.saturating_sub(ts_us),
        },
        name: Some(name_fn()),
        cat,
        ts_us,
        pid: PID_VIRTUAL,
        tid: lane,
    });
}

/// Snapshot (clone) all events recorded so far.
pub fn events() -> Vec<Event> {
    EVENTS.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Number of events recorded so far.
pub fn len() -> usize {
    EVENTS.lock().unwrap_or_else(|e| e.into_inner()).len()
}

/// Drop all recorded events.
pub fn reset() {
    EVENTS.lock().unwrap_or_else(|e| e.into_inner()).clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        crate::obs::reset();
        {
            let _g = span("test", "quiet");
            let _h = span_with("test", || "never built".to_string());
            virtual_span("test", || "nor this".to_string(), 0, 0.0, 1.0);
        }
        assert_eq!(len(), 0);
    }

    #[test]
    fn enabled_spans_balance_and_nest() {
        crate::obs::reset();
        crate::obs::enable(crate::obs::SPANS);
        {
            let _outer = span("test", "outer");
            let _inner = span("test", "inner");
        }
        virtual_span("test", || "vspan".to_string(), 3, 1.5, 2.5);
        let evs = events();
        crate::obs::reset();
        let begins = evs.iter().filter(|e| e.phase == Phase::Begin).count();
        let ends = evs.iter().filter(|e| e.phase == Phase::End).count();
        assert_eq!(begins, 2);
        assert_eq!(ends, 2);
        // Inner span must close before outer (RAII drop order).
        assert_eq!(evs[1].name.as_deref(), Some("inner"));
        assert_eq!(evs[2].phase, Phase::End);
        let v = evs.iter().find(|e| e.pid == PID_VIRTUAL).expect("vspan");
        assert_eq!(v.tid, 3);
        assert_eq!(v.ts_us, 1_500_000);
        assert_eq!(
            v.phase,
            Phase::Complete {
                dur_us: 1_000_000
            }
        );
    }
}
