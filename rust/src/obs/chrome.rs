//! Chrome trace-event JSON export (Perfetto / `chrome://tracing`).
//!
//! The emitted document is the standard *JSON Object Format*:
//! `{"traceEvents":[...]}`, containing
//!
//! * `M` (metadata) events naming the two process lanes — pid 1
//!   "wall clock" for pipeline/search/verifier/fleet spans, pid 2
//!   "virtual (sim)" for sched spans;
//! * `B`/`E` duration events for wall spans and `X` complete events
//!   for virtual sched spans;
//! * `C` counter events per node carrying the W·s time-series
//!   (committed/dynamic/idle W), which Perfetto renders as the paper's
//!   Fig-5-style power track.

use std::path::Path;

use crate::obs::series::PowerStep;
use crate::obs::span::{Event, Phase, PID_VIRTUAL, PID_WALL};
use crate::util::json::Json;
use crate::Result;

fn meta_event(pid: u32, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(0.0)),
        ("name", Json::str("process_name")),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

fn span_event(ev: &Event) -> Json {
    let mut pairs = vec![
        (
            "ph",
            Json::str(match ev.phase {
                Phase::Begin => "B",
                Phase::End => "E",
                Phase::Complete { .. } => "X",
            }),
        ),
        ("ts", Json::num(ev.ts_us as f64)),
        ("pid", Json::num(ev.pid as f64)),
        ("tid", Json::num(ev.tid as f64)),
    ];
    if let Some(name) = &ev.name {
        pairs.push(("name", Json::str(name.as_str())));
        pairs.push(("cat", Json::str(ev.cat)));
    }
    if let Phase::Complete { dur_us } = ev.phase {
        pairs.push(("dur", Json::num(dur_us as f64)));
    }
    Json::obj(pairs)
}

fn counter_event(step: &PowerStep) -> Json {
    Json::obj(vec![
        ("ph", Json::str("C")),
        ("ts", Json::num((step.t_s * 1e6).round().max(0.0))),
        ("pid", Json::num(PID_VIRTUAL as f64)),
        ("tid", Json::num(0.0)),
        ("name", Json::str(format!("node{}.power_w", step.node))),
        (
            "args",
            Json::obj(vec![
                ("committed_w", Json::num(step.committed_w)),
                ("dynamic_w", Json::num(step.dynamic_w)),
                ("idle_w", Json::num(step.idle_w)),
            ]),
        ),
    ])
}

/// Build the trace document from explicit event/series snapshots.
pub fn trace_json(events: &[Event], steps: &[PowerStep]) -> Json {
    let mut all = vec![
        meta_event(PID_WALL, "wall clock"),
        meta_event(PID_VIRTUAL, "virtual (sim)"),
    ];
    all.extend(events.iter().map(span_event));
    all.extend(steps.iter().map(counter_event));
    Json::obj(vec![("traceEvents", Json::arr(all))])
}

/// Build the trace document from the current global span buffer and
/// power series.
pub fn export() -> Json {
    trace_json(&crate::obs::span::events(), &crate::obs::series::power_steps())
}

/// Write the current trace to `path` as compact JSON.
pub fn write(path: &Path) -> Result<()> {
    std::fs::write(path, export().to_string_compact() + "\n")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_json_is_valid_and_balanced() {
        let events = vec![
            Event {
                phase: Phase::Begin,
                name: Some("step".into()),
                cat: "test",
                ts_us: 10,
                pid: PID_WALL,
                tid: 1,
            },
            Event {
                phase: Phase::End,
                name: None,
                cat: "test",
                ts_us: 20,
                pid: PID_WALL,
                tid: 1,
            },
        ];
        let steps = vec![PowerStep {
            t_s: 0.5,
            node: 2,
            committed_w: 300.0,
            dynamic_w: 120.0,
            idle_w: 40.0,
        }];
        let doc = trace_json(&events, &steps);
        let parsed = crate::util::json::parse(&doc.to_string_compact()).expect("valid JSON");
        let evs = parsed
            .get("traceEvents")
            .and_then(|t| t.as_arr())
            .expect("traceEvents array");
        // 2 metadata + B + E + C
        assert_eq!(evs.len(), 5);
        let phs: Vec<&str> = evs
            .iter()
            .filter_map(|e| e.get("ph").and_then(|p| p.as_str()))
            .collect();
        assert_eq!(phs, vec!["M", "M", "B", "E", "C"]);
        let c = &evs[4];
        assert_eq!(
            c.get("name").and_then(|n| n.as_str()),
            Some("node2.power_w")
        );
        assert_eq!(
            c.get("args")
                .and_then(|a| a.get("committed_w"))
                .and_then(|v| v.as_f64()),
            Some(300.0)
        );
    }
}
