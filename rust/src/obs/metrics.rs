//! Dependency-free metrics registry: named counters, gauges, and
//! fixed-bucket log2 histograms.
//!
//! All values live in `Relaxed` atomics. As with the PR 8 cache
//! counters, `Relaxed` is *exact* here, not approximate: `fetch_add`
//! is an atomic read-modify-write, so no increment can be lost — the
//! relaxation only forgoes ordering *between different* variables,
//! which nothing here relies on. Totals are read either after worker
//! threads have been joined or from the thread that produced them, so
//! reconciliation against e.g. the cache hit/miss ledger or the sched
//! admission/drop counts is equality, not approximation
//! (`tests/obs.rs` asserts exactly that).
//!
//! The registry itself (name → handle map) sits behind a `Mutex`, paid
//! only when metrics are enabled; [`reset`] zeroes values but never
//! removes entries, so handles obtained via [`counter`] stay valid for
//! the life of the process.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Number of log2 histogram buckets: bucket `i` counts values `v` with
/// `64 - v.leading_zeros() == i`, i.e. `2^(i-1) <= v < 2^i` (bucket 0
/// holds exactly `v == 0`).
pub const HIST_BUCKETS: usize = 65;

/// Fixed-bucket log2 histogram over `u64` samples.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Histogram {
    fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one sample.
    pub fn observe(&self, v: u64) {
        let idx = 64 - v.leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Non-empty buckets as `(bucket_index, count)` pairs.
    pub fn nonzero(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then_some((i, n))
            })
            .collect()
    }

    fn zero(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
    }
}

#[derive(Default)]
struct Registry {
    counters: BTreeMap<String, Arc<AtomicU64>>,
    gauges: BTreeMap<String, Arc<AtomicU64>>, // f64 bit patterns
    histograms: BTreeMap<String, Arc<Histogram>>,
}

fn registry() -> &'static Mutex<Registry> {
    static R: OnceLock<Mutex<Registry>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Registry::default()))
}

fn lock() -> std::sync::MutexGuard<'static, Registry> {
    registry().lock().unwrap_or_else(|e| e.into_inner())
}

/// Get (registering on first use) the counter handle for `name`. The
/// handle is not gated on the enable flag — callers that cache a
/// handle must gate their own `fetch_add` with
/// [`enabled`](super::enabled).
pub fn counter(name: &str) -> Arc<AtomicU64> {
    let mut r = lock();
    if let Some(c) = r.counters.get(name) {
        return Arc::clone(c);
    }
    let c = Arc::new(AtomicU64::new(0));
    r.counters.insert(name.to_string(), Arc::clone(&c));
    c
}

/// Add `delta` to counter `name`. No-op when metrics are disabled.
#[inline]
pub fn add(name: &str, delta: u64) {
    if !super::enabled(super::METRICS) {
        return;
    }
    counter(name).fetch_add(delta, Ordering::Relaxed);
}

/// Current value of counter `name` (0 if never registered).
pub fn counter_value(name: &str) -> u64 {
    lock()
        .counters
        .get(name)
        .map(|c| c.load(Ordering::Relaxed))
        .unwrap_or(0)
}

/// Set gauge `name` to `v`. No-op when metrics are disabled.
#[inline]
pub fn gauge_set(name: &str, v: f64) {
    if !super::enabled(super::METRICS) {
        return;
    }
    let mut r = lock();
    let g = r
        .gauges
        .entry(name.to_string())
        .or_insert_with(|| Arc::new(AtomicU64::new(0)));
    g.store(v.to_bits(), Ordering::Relaxed);
}

/// Current value of gauge `name` (`None` if never set).
pub fn gauge_value(name: &str) -> Option<f64> {
    lock()
        .gauges
        .get(name)
        .map(|g| f64::from_bits(g.load(Ordering::Relaxed)))
}

/// Record `v` into the log2 histogram `name`. No-op when metrics are
/// disabled.
#[inline]
pub fn observe(name: &str, v: u64) {
    if !super::enabled(super::METRICS) {
        return;
    }
    let h = {
        let mut r = lock();
        Arc::clone(
            r.histograms
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new())),
        )
    };
    h.observe(v);
}

/// Histogram handle for `name` (`None` if never observed).
pub fn histogram(name: &str) -> Option<Arc<Histogram>> {
    lock().histograms.get(name).map(Arc::clone)
}

/// Snapshot the whole registry as JSON:
/// `{"counters":{..}, "gauges":{..}, "histograms":{name:{"count":n,
/// "buckets":[[log2_bucket, count],..]}}}`. Keys are sorted (BTreeMap)
/// so the dump is stable.
pub fn snapshot() -> Json {
    let r = lock();
    let counters = Json::obj(
        r.counters
            .iter()
            .map(|(k, v)| (k.as_str(), Json::num(v.load(Ordering::Relaxed) as f64)))
            .collect(),
    );
    let gauges = Json::obj(
        r.gauges
            .iter()
            .map(|(k, v)| {
                (
                    k.as_str(),
                    Json::num(f64::from_bits(v.load(Ordering::Relaxed))),
                )
            })
            .collect(),
    );
    let histograms = Json::obj(
        r.histograms
            .iter()
            .map(|(k, h)| {
                let buckets = Json::arr(
                    h.nonzero()
                        .into_iter()
                        .map(|(i, n)| Json::arr(vec![Json::num(i as f64), Json::num(n as f64)]))
                        .collect(),
                );
                (
                    k.as_str(),
                    Json::obj(vec![
                        ("count", Json::num(h.count() as f64)),
                        ("buckets", buckets),
                    ]),
                )
            })
            .collect(),
    );
    Json::obj(vec![
        ("counters", counters),
        ("gauges", gauges),
        ("histograms", histograms),
    ])
}

/// Zero every counter, gauge, and histogram. Entries (and therefore
/// cached handles) are kept.
pub fn reset() {
    let r = lock();
    for c in r.counters.values() {
        c.store(0, Ordering::Relaxed);
    }
    for g in r.gauges.values() {
        g.store(0f64.to_bits(), Ordering::Relaxed);
    }
    for h in r.histograms.values() {
        h.zero();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_metrics_record_nothing() {
        crate::obs::reset();
        add("test.disabled", 5);
        observe("test.disabled.h", 5);
        assert_eq!(counter_value("test.disabled"), 0);
        assert!(histogram("test.disabled.h").is_none());
    }

    #[test]
    fn counters_are_exact_across_threads() {
        crate::obs::reset();
        crate::obs::enable(crate::obs::METRICS);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(|| {
                    for _ in 0..1000 {
                        add("test.exact", 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter_value("test.exact"), 4000);
        crate::obs::reset();
        // reset zeroes but keeps the entry.
        assert_eq!(counter_value("test.exact"), 0);
    }

    #[test]
    fn histogram_log2_bucketing() {
        crate::obs::reset();
        crate::obs::enable(crate::obs::METRICS);
        for v in [0u64, 1, 2, 3, 4, 1024] {
            observe("test.h", v);
        }
        let h = histogram("test.h").unwrap();
        assert_eq!(h.count(), 6);
        // 0 -> bucket 0; 1 -> 1; 2,3 -> 2; 4 -> 3; 1024 -> 11.
        assert_eq!(h.nonzero(), vec![(0, 1), (1, 1), (2, 2), (3, 1), (11, 1)]);
        let snap = snapshot();
        assert!(snap.get("histograms").and_then(|h| h.get("test.h")).is_some());
        crate::obs::reset();
    }
}
