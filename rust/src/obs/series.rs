//! Deterministic W·s time-series: the paper's time-resolved power
//! curve, reconstructed from the sched simulation in virtual time.
//!
//! Two row kinds:
//!
//! * [`PowerStep`] — one row per admission/completion transition of a
//!   node: simulated time, fleet committed W at that instant, the
//!   node's dynamic W, and its instantaneous ungated accelerator idle
//!   W. Recorded from `SimCore::start_job` / `remove_running`, which
//!   both sched engines share — so the series is identical between the
//!   event-driven and legacy engines by construction.
//! * [`IdleFold`] — one row per idle-ledger fold (`IdleLedger::fold`),
//!   mirroring the exact `idle_w × charged_s` / `× gated_s` terms the
//!   W·s ledger sums, in the same fold order.
//!
//! Rows are sorted on export by their full `f64` bit patterns (all
//! values are non-negative, so `to_bits` ordering is numeric ordering)
//! — parallel-federation clusters may interleave appends, but the
//! exported series is still bit-identical per seed.

use std::sync::Mutex;

use crate::util::json::Json;

/// One committed/dynamic/idle power sample at a virtual-time step.
#[derive(Debug, Clone, Copy)]
pub struct PowerStep {
    /// Simulated time of the transition, seconds.
    pub t_s: f64,
    /// Node index within its cluster.
    pub node: u32,
    /// Fleet-wide committed W after the transition.
    pub committed_w: f64,
    /// Sum of dynamic W of jobs running on this node.
    pub dynamic_w: f64,
    /// Instantaneous ungated accelerator idle W on this node.
    pub idle_w: f64,
}

/// One idle-ledger fold term (`idle_w` over a charged/gated split).
#[derive(Debug, Clone, Copy)]
pub struct IdleFold {
    /// Accelerator idle draw, W.
    pub idle_w: f64,
    /// Seconds charged at full idle draw.
    pub charged_s: f64,
    /// Seconds spent power-gated.
    pub gated_s: f64,
}

static POWER: Mutex<Vec<PowerStep>> = Mutex::new(Vec::new());
static IDLE: Mutex<Vec<IdleFold>> = Mutex::new(Vec::new());

/// Record a power step. No-op when the series pillar is disabled.
#[inline]
pub fn record_power_step(step: PowerStep) {
    if !super::enabled(super::SERIES) {
        return;
    }
    POWER.lock().unwrap_or_else(|e| e.into_inner()).push(step);
}

/// Record an idle fold. No-op when the series pillar is disabled.
#[inline]
pub fn record_idle_fold(fold: IdleFold) {
    if !super::enabled(super::SERIES) {
        return;
    }
    IDLE.lock().unwrap_or_else(|e| e.into_inner()).push(fold);
}

/// Snapshot of the power steps, sorted deterministically.
pub fn power_steps() -> Vec<PowerStep> {
    let mut v = POWER.lock().unwrap_or_else(|e| e.into_inner()).clone();
    v.sort_by_key(|s| {
        (
            s.t_s.to_bits(),
            s.node,
            s.committed_w.to_bits(),
            s.dynamic_w.to_bits(),
            s.idle_w.to_bits(),
        )
    });
    v
}

/// Snapshot of the idle folds, sorted deterministically.
pub fn idle_folds() -> Vec<IdleFold> {
    let mut v = IDLE.lock().unwrap_or_else(|e| e.into_inner()).clone();
    v.sort_by_key(|f| (f.idle_w.to_bits(), f.charged_s.to_bits(), f.gated_s.to_bits()));
    v
}

/// Export the whole series as JSON:
/// `{"power_steps":[{"t_s":..,"node":..,"committed_w":..,
/// "dynamic_w":..,"idle_w":..},..], "idle_folds":[..]}`.
pub fn to_json() -> Json {
    let steps = power_steps()
        .into_iter()
        .map(|s| {
            Json::obj(vec![
                ("t_s", Json::num(s.t_s)),
                ("node", Json::num(s.node as f64)),
                ("committed_w", Json::num(s.committed_w)),
                ("dynamic_w", Json::num(s.dynamic_w)),
                ("idle_w", Json::num(s.idle_w)),
            ])
        })
        .collect();
    let folds = idle_folds()
        .into_iter()
        .map(|f| {
            Json::obj(vec![
                ("idle_w", Json::num(f.idle_w)),
                ("charged_s", Json::num(f.charged_s)),
                ("gated_s", Json::num(f.gated_s)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("power_steps", Json::arr(steps)),
        ("idle_folds", Json::arr(folds)),
    ])
}

/// Drop all recorded rows.
pub fn reset() {
    POWER.lock().unwrap_or_else(|e| e.into_inner()).clear();
    IDLE.lock().unwrap_or_else(|e| e.into_inner()).clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_series_records_nothing() {
        crate::obs::reset();
        record_power_step(PowerStep {
            t_s: 1.0,
            node: 0,
            committed_w: 100.0,
            dynamic_w: 50.0,
            idle_w: 10.0,
        });
        assert!(power_steps().is_empty());
    }

    #[test]
    fn export_sorts_interleaved_appends() {
        crate::obs::reset();
        crate::obs::enable(crate::obs::SERIES);
        for (t, node) in [(2.0, 1), (1.0, 0), (2.0, 0), (1.0, 1)] {
            record_power_step(PowerStep {
                t_s: t,
                node,
                committed_w: 0.0,
                dynamic_w: 0.0,
                idle_w: 0.0,
            });
        }
        let steps = power_steps();
        crate::obs::reset();
        let order: Vec<(f64, u32)> = steps.iter().map(|s| (s.t_s, s.node)).collect();
        assert_eq!(order, vec![(1.0, 0), (1.0, 1), (2.0, 0), (2.0, 1)]);
    }
}
