//! Zero-overhead telemetry: span tracing, a metrics registry, and the
//! deterministic W·s time-series (DESIGN.md §16).
//!
//! Three independently switchable pillars, all **off by default**:
//!
//! * [`SPANS`] — thread-aware RAII spans ([`span::span`]) plus
//!   virtual-time spans keyed by the sched simulation clock
//!   ([`span::virtual_span`]), exportable as Chrome trace-event JSON
//!   ([`chrome`]) loadable in Perfetto / `chrome://tracing`.
//! * [`METRICS`] — dependency-free counters / gauges / log2 histograms
//!   ([`metrics`]), dumped as JSON and rendered by `enadapt obs`.
//! * [`SERIES`] — the per-node committed-W / dynamic-W / idle-W step
//!   series in virtual time ([`series`]), the paper's Fig-5-style power
//!   curve, bit-identical per seed.
//!
//! ## Zero cost when disabled
//!
//! Every recording entry point starts with [`enabled`] — a single
//! `Relaxed` load of one process-global `AtomicU8`, roughly one L1 hit
//! (~1 ns) plus a predictable branch. No allocation, no formatting, no
//! lock is reached on the disabled path; `span` call sites take a
//! `&str` (or a lazy closure via [`span::span_with`]) so even the name
//! is never built. The bit-identical-per-seed contracts of PRs 4/6/8/9
//! hold trivially because telemetry is purely observational: it reads
//! values the simulation already computed and never feeds anything
//! back. `benches/obs_overhead.rs` enforces the off-path contract
//! (BENCH_obs.json).
//!
//! ## Wall time vs virtual time
//!
//! Wall-clock spans (pipeline steps, search strategies, verifier
//! trials, fleet jobs) carry timestamps from a process-epoch
//! [`std::time::Instant`] and render under pid 1 ("wall"). Sched spans
//! carry *simulated* timestamps and render under pid 2 ("virtual") —
//! that half of the trace is a pure function of trace × config × seed.

pub mod chrome;
pub mod metrics;
pub mod series;
pub mod span;

use std::sync::atomic::{AtomicU8, Ordering};

/// Pillar bit: span tracing (wall + virtual time).
pub const SPANS: u8 = 1 << 0;
/// Pillar bit: metrics registry (counters / gauges / histograms).
pub const METRICS: u8 = 1 << 1;
/// Pillar bit: deterministic W·s time-series.
pub const SERIES: u8 = 1 << 2;
/// All pillars at once.
pub const ALL: u8 = SPANS | METRICS | SERIES;

static ENABLED: AtomicU8 = AtomicU8::new(0);

/// True if any pillar in `mask` is enabled. This is the *only* check on
/// the disabled hot path: one `Relaxed` atomic load and a branch.
#[inline(always)]
pub fn enabled(mask: u8) -> bool {
    ENABLED.load(Ordering::Relaxed) & mask != 0
}

/// Enable the pillars in `mask` (other pillars keep their state).
pub fn enable(mask: u8) {
    ENABLED.fetch_or(mask, Ordering::Relaxed);
}

/// Disable the pillars in `mask` (other pillars keep their state).
pub fn disable(mask: u8) {
    ENABLED.fetch_and(!mask, Ordering::Relaxed);
}

/// Disable everything and drop all recorded state: span events, series
/// rows, and metric *values* (registered metric handles stay valid —
/// values are zeroed, entries are never removed).
pub fn reset() {
    ENABLED.store(0, Ordering::Relaxed);
    span::reset();
    metrics::reset();
    series::reset();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pillar_masking_is_independent() {
        reset();
        assert!(!enabled(ALL));
        enable(SPANS);
        assert!(enabled(SPANS));
        assert!(!enabled(METRICS));
        assert!(!enabled(SERIES));
        enable(METRICS | SERIES);
        assert!(enabled(ALL));
        disable(SPANS);
        assert!(!enabled(SPANS));
        assert!(enabled(METRICS));
        reset();
        assert!(!enabled(ALL));
    }
}
