//! Host (small-core CPU) model — the baseline every offload pattern is
//! compared against, and the executor of whatever loops stay on the CPU.
//!
//! The throughput constant is *calibrated*, not a datasheet number: the
//! paper's testbed runs scalar C (gcc, no autovectorization) where a
//! sinf/cosf pair costs ~100 ns, so effective weighted-FLOP throughput is
//! ~1 GFLOP/s. With that, full-size MRI-Q (64³ voxels × 2048 k-samples)
//! lands at the paper's ~14 s CPU-only time (Fig. 5).

use super::traits::NestWork;

/// Host CPU model.
#[derive(Debug, Clone, Copy)]
pub struct CpuModel {
    /// Effective weighted-FLOP throughput, FLOP/s (scalar code).
    pub gflops: f64,
    /// Effective memory bandwidth for streaming loops, bytes/s.
    pub mem_bw: f64,
    /// Extra server draw while the CPU is busy, Watts (R740: ~121 W busy
    /// vs ~105 W idle baseline → 16 W).
    pub active_w: f64,
}

impl CpuModel {
    /// Calibrated R740-class host (see module docs).
    pub fn r740() -> Self {
        Self {
            gflops: 1.0e9,
            mem_bw: 8.0e9,
            active_w: 16.0,
        }
    }

    /// Roofline execution time of a nest on the host.
    pub fn nest_time_s(&self, w: &NestWork) -> f64 {
        (w.flops / self.gflops).max(w.bytes / self.mem_bw)
    }

    /// Time for straight-line (non-loop) work given weighted FLOPs+bytes.
    pub fn straightline_time_s(&self, flops: f64, bytes: f64) -> f64 {
        (flops / self.gflops).max(bytes / self.mem_bw)
    }

    /// Component-tagged draw of a host-busy phase (prologue, epilogue and
    /// loops that stay on the CPU): idle base plus the CPU's active draw.
    pub fn busy_power(&self, idle_w: f64) -> crate::power::ComponentPower {
        crate::power::ComponentPower::host_busy(idle_w, self.active_w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::canalyze::OpCensus;

    fn work(flops: f64, bytes: f64) -> NestWork {
        NestWork {
            flops,
            bytes,
            transfer_bytes: 0.0,
            entries: 1.0,
            trips: 1.0,
            census: OpCensus::default(),
        }
    }

    #[test]
    fn compute_bound_uses_flops() {
        let cpu = CpuModel::r740();
        let t = cpu.nest_time_s(&work(2.0e9, 1.0e6));
        assert!((t - 2.0).abs() < 1e-9);
    }

    #[test]
    fn memory_bound_uses_bandwidth() {
        let cpu = CpuModel::r740();
        let t = cpu.nest_time_s(&work(1.0e6, 16.0e9));
        assert!((t - 2.0).abs() < 1e-9);
    }

    #[test]
    fn full_scale_mriq_lands_near_14s() {
        // 64^3 voxels × 2048 k-samples, ~26 weighted FLOPs per inner
        // iteration (2 specials ×8 + ~10 mul/add) → ~1.4e10 FLOPs.
        let cpu = CpuModel::r740();
        let flops = 262_144.0 * 2048.0 * 26.0;
        let t = cpu.nest_time_s(&work(flops, flops * 0.6));
        assert!((10.0..20.0).contains(&t), "t = {t}");
    }
}
