//! Device-model abstractions for the verification environment.
//!
//! The paper measures candidate offload patterns on real hardware (Intel
//! PAC Arria10 FPGA, NVIDIA GPU, many-core CPU). This repo has none of
//! those, so each migration destination is an analytic model that maps a
//! loop nest's *work summary* to a kernel-time/transfer-time/power
//! estimate. The models are calibrated so MRI-Q reproduces the paper's
//! Fig. 5 decision landscape (see DESIGN.md §2 and §6).

use crate::canalyze::OpCensus;
use crate::power::ComponentPower;

/// Offload destinations (the paper's §3.3 mixed environment).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// Small-core host CPU (the baseline, not an offload target).
    Cpu,
    /// Many-core CPU (OpenMP target; same memory space).
    ManyCore,
    /// GPU (CUDA/OpenACC target; PCIe transfers).
    Gpu,
    /// FPGA (OpenCL target; PCIe transfers, hours-long synthesis).
    Fpga,
}

impl DeviceKind {
    /// Human-readable name used in reports.
    pub fn name(self) -> &'static str {
        match self {
            DeviceKind::Cpu => "cpu",
            DeviceKind::ManyCore => "many-core-cpu",
            DeviceKind::Gpu => "gpu",
            DeviceKind::Fpga => "fpga",
        }
    }

    /// Inverse of [`DeviceKind::name`] (used when reloading persisted
    /// measurement-cache entries).
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "cpu" => Some(DeviceKind::Cpu),
            "many-core-cpu" => Some(DeviceKind::ManyCore),
            "gpu" => Some(DeviceKind::Gpu),
            "fpga" => Some(DeviceKind::Fpga),
            _ => None,
        }
    }
}

impl std::fmt::Display for DeviceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Full-problem-scale work summary of one offloadable loop nest, computed
/// by [`crate::verifier::AppModel`] from the analyzer's profile.
#[derive(Debug, Clone, Copy)]
pub struct NestWork {
    /// Weighted floating-point operations (divides ×4, specials ×8).
    pub flops: f64,
    /// Memory traffic in bytes.
    pub bytes: f64,
    /// CPU↔device payload per transfer event, bytes.
    pub transfer_bytes: f64,
    /// Kernel launches per application run (loop-entry count).
    pub entries: f64,
    /// Loop-nest iterations per application run (innermost trip total).
    pub trips: f64,
    /// Static per-iteration census of the innermost hot body (FPGA
    /// resource estimation).
    pub census: OpCensus,
}

impl NestWork {
    /// Arithmetic intensity (FLOP/byte).
    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes.max(1.0)
    }
}

/// How CPU↔device variable transfers are scheduled — the paper's §3.1
/// transfer optimization: naive directive insertion transfers at every
/// kernel entry; the proposed method batches variables at the outermost
/// level so payloads cross PCIe once per run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TransferMode {
    /// Transfer per loop entry (what a naive OpenACC annotation does).
    PerEntry,
    /// Consolidated: variables batched at the top level, one round trip.
    #[default]
    Batched,
}

/// Per-nest execution estimate on a device.
#[derive(Debug, Clone, Copy, Default)]
pub struct KernelEstimate {
    /// Pure device compute time, seconds.
    pub compute_s: f64,
    /// CPU↔device transfer time, seconds.
    pub transfer_s: f64,
    /// Launch/dispatch overhead, seconds.
    pub launch_s: f64,
    /// Extra device power draw while the kernel runs, Watts.
    pub dyn_power_w: f64,
    /// Extra *host* draw during the device phase (driver/polling), Watts.
    pub host_power_w: f64,
}

impl KernelEstimate {
    /// Total wall time of the offloaded nest.
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.transfer_s + self.launch_s
    }

    /// Component-tagged draw during the CPU↔device transfer phase: the
    /// host CPU is busy driving DMA (full active draw) and the transfer
    /// machinery adds the device's host-side drive power.
    pub fn transfer_power(&self, idle_w: f64, host_active_w: f64) -> ComponentPower {
        ComponentPower {
            idle_w,
            host_cpu_w: host_active_w,
            accelerator_w: 0.0,
            transfer_w: self.host_power_w,
        }
    }

    /// Component-tagged draw during the kernel phase: the accelerator runs
    /// at its dynamic draw while the host only polls the driver.
    pub fn kernel_power(&self, idle_w: f64) -> ComponentPower {
        ComponentPower {
            idle_w,
            host_cpu_w: self.host_power_w,
            accelerator_w: self.dyn_power_w,
            transfer_w: 0.0,
        }
    }
}

/// A migration destination the verification environment can try.
pub trait Accelerator: Send + Sync {
    /// Which destination this is.
    fn kind(&self) -> DeviceKind;

    /// Can this nest run on the device at all? FPGA rejects nests whose
    /// pipeline does not fit the resource budget (the paper's precompile
    /// narrowing); other devices accept everything.
    fn supports(&self, work: &NestWork) -> Result<(), String>;

    /// Estimate execution of the nest.
    fn estimate(&self, work: &NestWork, xfer: TransferMode) -> KernelEstimate;

    /// One-time preparation latency charged per *measured pattern* in the
    /// verification environment (FPGA: OpenCL synthesis, hours; GPU:
    /// OpenACC compile, seconds). This is search cost, not run cost.
    fn prep_latency_s(&self, work: &NestWork) -> f64 {
        let _ = work;
        0.0
    }

    /// Device idle draw added to the server baseline while installed.
    fn idle_w(&self) -> f64;
}
