//! Hardware models of the verification environment's migration
//! destinations (the paper's Fig. 4 testbed substitute): host CPU,
//! many-core CPU, GPU and FPGA, calibrated so MRI-Q lands in the Fig. 5
//! bands (14 s / 121 W CPU-only → ≈2 s / ≈111 W offloaded); the FPGA
//! resource/synthesis models behind the §3.2 precompile narrowing; and
//! the cluster node capacity model ([`NodeSpec`] / [`NodeOccupancy`]) the
//! power-budget fleet scheduler packs jobs onto. See DESIGN.md §2 for the
//! substitution rationale and §6 for calibration.

pub mod cpu;
pub mod fpga;
pub mod gpu;
pub mod manycore;
pub mod resources;
pub mod synth;
pub mod traits;

pub use cpu::CpuModel;
pub use fpga::FpgaModel;
pub use gpu::GpuModel;
pub use manycore::ManyCoreModel;
pub use resources::{estimate_lane, FpgaResources, NodeOccupancy, NodeSpec, OpCosts};
pub use synth::{SynthEstimate, SynthModel};
pub use traits::{Accelerator, DeviceKind, KernelEstimate, NestWork, TransferMode};
