//! Hardware models of the verification environment's migration
//! destinations (Fig. 4 testbed substitute): host CPU, many-core CPU, GPU
//! and FPGA, plus the FPGA resource/synthesis models used by the paper's
//! precompile narrowing. See DESIGN.md §2 for the substitution rationale
//! and §6 for calibration.

pub mod cpu;
pub mod fpga;
pub mod gpu;
pub mod manycore;
pub mod resources;
pub mod synth;
pub mod traits;

pub use cpu::CpuModel;
pub use fpga::FpgaModel;
pub use gpu::GpuModel;
pub use manycore::ManyCoreModel;
pub use resources::{estimate_lane, FpgaResources, OpCosts};
pub use synth::{SynthEstimate, SynthModel};
pub use traits::{Accelerator, DeviceKind, KernelEstimate, NestWork, TransferMode};
