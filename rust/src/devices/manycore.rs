//! Many-core CPU model (OpenMP migration destination).
//!
//! §3.3 of the paper orders verification many-core → GPU → FPGA because
//! the many-core is closest to the host: same memory space (no PCIe
//! payload), trivial "compilation" (OpenMP pragma), cheap verification —
//! but also the smallest gains and a sizable all-cores power draw.

use super::cpu::CpuModel;
use super::traits::{Accelerator, DeviceKind, KernelEstimate, NestWork, TransferMode};

/// Many-core CPU (e.g. Xeon Phi-class or a second high-core-count socket).
#[derive(Debug, Clone, Copy)]
pub struct ManyCoreModel {
    /// Host model the speedup is relative to.
    pub host: CpuModel,
    /// Usable parallel cores.
    pub cores: f64,
    /// Parallel efficiency in (0,1] (scheduling + NUMA losses).
    pub efficiency: f64,
    /// Aggregate memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Per-parallel-region fork/join overhead, seconds.
    pub fork_join_s: f64,
    /// Extra draw while all cores are busy, Watts.
    pub active_w: f64,
    /// Idle draw added to the server baseline, Watts.
    pub idle_extra_w: f64,
}

impl ManyCoreModel {
    /// 16-core OpenMP target, calibrated alongside [`CpuModel::r740`]:
    /// ~10× effective speedup at a hefty all-cores draw, so it beats the
    /// CPU on time but loses to the FPGA on energy (the §3.3 landscape).
    pub fn xeon16() -> Self {
        Self {
            host: CpuModel::r740(),
            cores: 16.0,
            efficiency: 0.62,
            mem_bw: 40.0e9,
            fork_join_s: 30.0e-6,
            active_w: 68.0,
            idle_extra_w: 0.0,
        }
    }
}

impl Accelerator for ManyCoreModel {
    fn kind(&self) -> DeviceKind {
        DeviceKind::ManyCore
    }

    fn supports(&self, _work: &NestWork) -> Result<(), String> {
        Ok(())
    }

    fn estimate(&self, w: &NestWork, _xfer: TransferMode) -> KernelEstimate {
        let parallel = self.cores * self.efficiency;
        let compute = (w.flops / (self.host.gflops * parallel)).max(w.bytes / self.mem_bw);
        KernelEstimate {
            compute_s: compute,
            transfer_s: 0.0, // shared memory space
            launch_s: self.fork_join_s * w.entries,
            dyn_power_w: self.active_w,
            host_power_w: 0.0, // the many-core *is* the host package
        }
    }

    fn prep_latency_s(&self, _work: &NestWork) -> f64 {
        // OpenMP pragma + recompile.
        20.0
    }

    fn idle_w(&self) -> f64 {
        self.idle_extra_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::canalyze::OpCensus;

    fn work(flops: f64, bytes: f64, entries: f64) -> NestWork {
        NestWork {
            flops,
            bytes,
            transfer_bytes: 4.0e6,
            entries,
            trips: 1000.0,
            census: OpCensus::default(),
        }
    }

    #[test]
    fn speedup_is_bounded_by_cores() {
        let mc = ManyCoreModel::xeon16();
        let w = work(10.0e9, 1.0e6, 1.0);
        let host_t = mc.host.nest_time_s(&w);
        let mc_t = mc.estimate(&w, TransferMode::Batched).total_s();
        let speedup = host_t / mc_t;
        assert!(speedup <= 16.0 + 1e-9, "speedup {speedup}");
        assert!(speedup > 8.0, "speedup {speedup}");
    }

    #[test]
    fn no_transfer_cost() {
        let mc = ManyCoreModel::xeon16();
        let e = mc.estimate(&work(1.0e9, 1.0e6, 5.0), TransferMode::PerEntry);
        assert_eq!(e.transfer_s, 0.0);
    }

    #[test]
    fn fork_join_scales_with_entries() {
        let mc = ManyCoreModel::xeon16();
        let a = mc.estimate(&work(1.0e9, 1.0e6, 1.0), TransferMode::Batched);
        let b = mc.estimate(&work(1.0e9, 1.0e6, 1000.0), TransferMode::Batched);
        assert!(b.launch_s > a.launch_s * 100.0);
    }

    #[test]
    fn memory_bound_nests_see_bandwidth_ceiling() {
        let mc = ManyCoreModel::xeon16();
        let w = work(1.0e6, 80.0e9, 1.0);
        let t = mc.estimate(&w, TransferMode::Batched).compute_s;
        assert!((t - 2.0).abs() < 1e-9);
    }
}
