//! FPGA synthesis model: lane replication, fit checking and compile-time
//! estimation — the paper's §3.2 "precompile" narrowing stage plus the
//! "several hours or more to compile OpenCL" cost that motivates narrowing
//! instead of GA search for FPGAs.

use super::resources::{estimate_lane, FpgaResources, OpCosts};
use crate::canalyze::OpCensus;

/// Synthesis outcome for a candidate loop body.
#[derive(Debug, Clone, Copy)]
pub struct SynthEstimate {
    /// Replication factor chosen (pipeline lanes running in parallel).
    pub lanes: u32,
    /// Resources of the replicated design.
    pub resources: FpgaResources,
    /// Peak utilization fraction vs the part's budget.
    pub utilization: f64,
    /// Whether the design fits (≤ util cap) at ≥ 1 lane.
    pub fits: bool,
    /// Full-compile wall time estimate, seconds (hours-scale).
    pub compile_s: f64,
    /// Precompile (resource-report) wall time, seconds (minutes-scale).
    pub precompile_s: f64,
}

/// Synthesis model configuration.
#[derive(Debug, Clone, Copy)]
pub struct SynthModel {
    /// Part budget.
    pub budget: FpgaResources,
    /// Per-op cost table.
    pub costs: OpCosts,
    /// Routable-utilization cap.
    pub util_cap: f64,
    /// Max lanes the memory system can feed.
    pub max_lanes: u32,
    /// Base full-compile time, seconds (place & route floor).
    pub compile_base_s: f64,
    /// Additional compile seconds per utilization point (congestion).
    pub compile_per_util_s: f64,
    /// Precompile (HLS front-end resource report) time, seconds.
    pub precompile_s: f64,
}

impl SynthModel {
    /// Intel PAC / Acceleration Stack 1.2 defaults: ~2 h base compiles
    /// growing toward 4–5 h for congested designs, ~3 min precompiles.
    pub fn arria10() -> Self {
        Self {
            budget: FpgaResources::arria10_gx(),
            costs: OpCosts::default(),
            util_cap: 0.85,
            max_lanes: 4,
            compile_base_s: 2.0 * 3600.0,
            compile_per_util_s: 3.0 * 3600.0,
            precompile_s: 180.0,
        }
    }

    /// Estimate synthesis of a loop body: replicate lanes while the design
    /// fits, then report resources and compile times.
    pub fn synthesize(&self, census: &OpCensus) -> SynthEstimate {
        let lane = estimate_lane(census, &self.costs);
        let mut lanes = 0u32;
        let mut chosen = FpgaResources::default();
        for k in 1..=self.max_lanes {
            let r = lane.scale(k as f64);
            if r.fits_in(&self.budget, self.util_cap) {
                lanes = k;
                chosen = r;
            } else {
                break;
            }
        }
        let fits = lanes >= 1;
        let utilization = if fits {
            chosen.utilization_vs(&self.budget)
        } else {
            lane.utilization_vs(&self.budget)
        };
        SynthEstimate {
            lanes: lanes.max(1),
            resources: if fits { chosen } else { lane },
            utilization,
            fits,
            compile_s: self.compile_base_s + self.compile_per_util_s * utilization,
            precompile_s: self.precompile_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn census(fadd: u64, fmul: u64, fspecial: u64, mem: u64) -> OpCensus {
        OpCensus {
            fadd,
            fmul,
            fdiv: 0,
            fspecial,
            iops: 4,
            loads: mem,
            stores: 1,
            calls: 0,
        }
    }

    #[test]
    fn small_body_replicates_to_max_lanes() {
        let m = SynthModel::arria10();
        let e = m.synthesize(&census(4, 5, 2, 4));
        assert!(e.fits);
        assert_eq!(e.lanes, m.max_lanes);
    }

    #[test]
    fn huge_body_does_not_fit() {
        let m = SynthModel::arria10();
        // 200 special-function cores blow the DSP budget even at 1 lane.
        let e = m.synthesize(&census(50, 300, 200, 40));
        assert!(!e.fits);
        assert!(e.utilization > m.util_cap);
    }

    #[test]
    fn compile_time_is_hours_scale_and_grows_with_congestion() {
        let m = SynthModel::arria10();
        let light = m.synthesize(&census(2, 2, 0, 2));
        let heavy = m.synthesize(&census(40, 60, 20, 10));
        assert!(light.compile_s >= 2.0 * 3600.0);
        assert!(heavy.compile_s > light.compile_s);
        assert!(light.precompile_s < 600.0, "precompile is minutes");
    }

    #[test]
    fn lanes_monotone_in_body_size() {
        let m = SynthModel::arria10();
        let small = m.synthesize(&census(2, 2, 1, 2)).lanes;
        let big = m.synthesize(&census(60, 80, 40, 20)).lanes;
        assert!(small >= big);
    }
}
