//! FPGA model (OpenCL migration destination) — the paper's §3.2/§4 device
//! (Intel PAC with Arria 10 GX + Acceleration Stack 1.2).
//!
//! Timing follows the HLS pipeline view: the synthesized kernel retires
//! one loop iteration per `II` clock cycles per replicated lane, so nest
//! time ≈ `trips · II / (lanes · f_clk)` plus PCIe transfers and launch
//! overhead. Resource fit and lane count come from [`SynthModel`]
//! (the precompile report), and full compiles cost hours — which is why
//! the flow narrows candidates instead of running a GA (§3.2).
//!
//! Calibration (DESIGN.md §6): with the default constants, full-size MRI-Q
//! (64³ voxels × 2048 k-samples, inner nest ≈5.4e8 iterations) runs in
//! ≈1.7 s on the FPGA and the whole offloaded app in ≈2 s vs 14 s CPU-only
//! at ≈111 W vs ≈121 W — the paper's Fig. 5 (223 vs 1,690 W·s).

use super::synth::{SynthEstimate, SynthModel};
use super::traits::{Accelerator, DeviceKind, KernelEstimate, NestWork, TransferMode};

/// FPGA device model.
#[derive(Debug, Clone, Copy)]
pub struct FpgaModel {
    /// Synthesis model (resources, lanes, compile times).
    pub synth: SynthModel,
    /// Kernel clock, Hz.
    pub clock_hz: f64,
    /// Achieved initiation interval (cycles per iteration per lane); >1
    /// captures dependence/memory stalls of real HLS results.
    pub ii: f64,
    /// DDR bandwidth on the card, bytes/s.
    pub ddr_bw: f64,
    /// PCIe effective bandwidth, bytes/s.
    pub pcie_bw: f64,
    /// Per-transfer fixed latency, seconds.
    pub pcie_latency_s: f64,
    /// Kernel launch overhead via the Acceleration Stack, seconds.
    pub launch_s: f64,
    /// Extra draw while the kernel runs, Watts (FPGAs are power-efficient:
    /// the paper measured only ≈111 W whole-server during FPGA compute vs
    /// ≈121 W during CPU compute).
    pub active_w: f64,
    /// Host draw while driving the FPGA, Watts.
    pub host_drive_w: f64,
    /// Idle draw added to the server baseline while installed, Watts.
    pub idle_extra_w: f64,
}

impl FpgaModel {
    /// Intel PAC Arria 10 GX, calibrated per module docs.
    pub fn arria10() -> Self {
        Self {
            synth: SynthModel::arria10(),
            clock_hz: 0.24e9,
            ii: 3.0,
            ddr_bw: 17.0e9,
            pcie_bw: 6.0e9,
            pcie_latency_s: 30.0e-6,
            launch_s: 200.0e-6,
            active_w: 4.0,
            host_drive_w: 2.0,
            idle_extra_w: 0.0,
        }
    }

    /// Synthesis estimate for a nest (exposed for the narrowing flow's
    /// reports).
    pub fn synthesis(&self, work: &NestWork) -> SynthEstimate {
        self.synth.synthesize(&work.census)
    }
}

impl Accelerator for FpgaModel {
    fn kind(&self) -> DeviceKind {
        DeviceKind::Fpga
    }

    fn supports(&self, work: &NestWork) -> Result<(), String> {
        let e = self.synthesis(work);
        if e.fits {
            Ok(())
        } else {
            Err(format!(
                "kernel does not fit the Arria10 budget (utilization {:.0}% > cap {:.0}%)",
                e.utilization * 100.0,
                self.synth.util_cap * 100.0
            ))
        }
    }

    fn estimate(&self, w: &NestWork, xfer: TransferMode) -> KernelEstimate {
        let e = self.synthesis(w);
        let lanes = e.lanes as f64;
        // Pipeline throughput, throttled by DDR feed rate.
        let iter_rate = (lanes * self.clock_hz / self.ii).min(
            self.ddr_bw / (w.census.bytes().max(4.0) / w.trips.max(1.0)).max(4.0) * 1.0,
        );
        let bytes_per_iter = if w.trips > 0.0 { w.bytes / w.trips } else { 4.0 };
        let feed_rate = self.ddr_bw / bytes_per_iter.max(1.0);
        let rate = (lanes * self.clock_hz / self.ii).min(feed_rate);
        let _ = iter_rate;
        let compute = w.trips / rate.max(1.0);
        let events = match xfer {
            TransferMode::Batched => 1.0,
            TransferMode::PerEntry => w.entries.max(1.0),
        };
        let transfer =
            events * (2.0 * w.transfer_bytes / self.pcie_bw + 2.0 * self.pcie_latency_s);
        KernelEstimate {
            compute_s: compute,
            transfer_s: transfer,
            launch_s: self.launch_s * w.entries.max(1.0),
            dyn_power_w: self.active_w,
            host_power_w: self.host_drive_w,
        }
    }

    fn prep_latency_s(&self, work: &NestWork) -> f64 {
        // Full OpenCL compile of the pattern: hours (this is what makes
        // FPGA verification trials expensive and forces narrowing).
        self.synthesis(work).compile_s
    }

    fn idle_w(&self) -> f64 {
        self.idle_extra_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::canalyze::OpCensus;

    /// The MRI-Q computeQ inner body census (≈ what the analyzer reports).
    fn mriq_census() -> OpCensus {
        OpCensus {
            fadd: 5,
            fmul: 6,
            fdiv: 0,
            fspecial: 2,
            iops: 6,
            loads: 4,
            stores: 0,
            calls: 0,
        }
    }

    fn mriq_full_work() -> NestWork {
        let trips = 262_144.0 * 2048.0;
        NestWork {
            flops: trips * 26.0,
            bytes: trips * 16.0,
            transfer_bytes: 5.5e6,
            entries: 1.0,
            trips,
            census: mriq_census(),
        }
    }

    #[test]
    fn mriq_kernel_time_matches_fig5_scale() {
        let fpga = FpgaModel::arria10();
        let e = fpga.estimate(&mriq_full_work(), TransferMode::Batched);
        // Fig. 5: whole app 2 s, kernel share ≈ 1.7 s.
        assert!(
            (1.2..2.4).contains(&e.total_s()),
            "kernel total {} s",
            e.total_s()
        );
    }

    #[test]
    fn mriq_fits_and_prep_is_hours() {
        let fpga = FpgaModel::arria10();
        let w = mriq_full_work();
        assert!(fpga.supports(&w).is_ok());
        let prep = fpga.prep_latency_s(&w);
        assert!(prep > 3600.0, "prep {prep} s should be hours-scale");
    }

    #[test]
    fn oversized_kernel_is_rejected() {
        let fpga = FpgaModel::arria10();
        let mut w = mriq_full_work();
        w.census = OpCensus {
            fadd: 100,
            fmul: 400,
            fdiv: 10,
            fspecial: 180,
            iops: 50,
            loads: 30,
            stores: 10,
            calls: 0,
        };
        assert!(fpga.supports(&w).is_err());
    }

    #[test]
    fn memory_bound_nest_is_throttled_by_ddr() {
        let fpga = FpgaModel::arria10();
        let trips = 1.0e8;
        let w = NestWork {
            flops: trips * 2.0,
            bytes: trips * 400.0, // 400 B per iteration — way past DDR feed
            transfer_bytes: 1.0e6,
            entries: 1.0,
            trips,
            census: OpCensus {
                fadd: 1,
                fmul: 1,
                fdiv: 0,
                fspecial: 0,
                iops: 2,
                loads: 100,
                stores: 0,
                calls: 0,
            },
        };
        let e = fpga.estimate(&w, TransferMode::Batched);
        let ddr_floor = w.bytes / fpga.ddr_bw;
        assert!(e.compute_s >= ddr_floor * 0.99, "DDR-throttled");
    }

    #[test]
    fn low_power_vs_gpu() {
        let fpga = FpgaModel::arria10();
        let gpu = super::super::gpu::GpuModel::tesla();
        let w = mriq_full_work();
        let ef = fpga.estimate(&w, TransferMode::Batched);
        let eg = gpu.estimate(&w, TransferMode::Batched);
        assert!(ef.dyn_power_w < eg.dyn_power_w / 5.0);
    }
}
