//! Resource accounting for the verification environment and the fleet
//! scheduler.
//!
//! Two resource granularities live here:
//!
//! * **FPGA on-chip resources** — Flip-Flops, Lookup Tables, DSP blocks
//!   and on-chip RAM. The paper's §3.2 narrows FPGA candidates by
//!   *precompiling* OpenCL and reading the reported resource usage ("the
//!   resources such as Flip Flop and Lookup Table to be created are known
//!   in the middle of compilation"); [`estimate_lane`] is the analytic
//!   stand-in for that mid-compile report.
//! * **Cluster node capacity** — [`NodeSpec`] describes one simulated
//!   server of the production cluster (how many host/GPU/FPGA/many-core
//!   job slots it offers and what its chassis and per-accelerator idle
//!   draws are), and [`NodeOccupancy`] tracks which slots are busy. The
//!   power-budget fleet scheduler ([`crate::coordinator::sched`]) packs
//!   arriving jobs onto these nodes under a fleet-wide Watt cap.

use super::traits::DeviceKind;
use crate::canalyze::OpCensus;

/// Resource vector of an FPGA design (or budget of a part).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FpgaResources {
    /// Adaptive logic lookup tables.
    pub luts: f64,
    /// Flip-flops / registers.
    pub ffs: f64,
    /// DSP blocks (hard multipliers).
    pub dsps: f64,
    /// On-chip RAM, kilobytes.
    pub ram_kb: f64,
}

impl FpgaResources {
    /// Intel Arria 10 GX 1150 (the paper's Intel PAC card), minus the
    /// board-support-package share the Acceleration Stack reserves.
    pub fn arria10_gx() -> Self {
        Self {
            luts: 1_150_000.0 * 0.75,
            ffs: 1_708_800.0 * 0.75,
            dsps: 1_518.0 * 0.9,
            ram_kb: 53_000.0 * 0.8,
        }
    }

    /// Scale by a replication factor (pipeline lanes).
    pub fn scale(&self, k: f64) -> Self {
        Self {
            luts: self.luts * k,
            ffs: self.ffs * k,
            dsps: self.dsps * k,
            ram_kb: self.ram_kb * k,
        }
    }

    /// Component-wise addition.
    pub fn plus(&self, other: &Self) -> Self {
        Self {
            luts: self.luts + other.luts,
            ffs: self.ffs + other.ffs,
            dsps: self.dsps + other.dsps,
            ram_kb: self.ram_kb + other.ram_kb,
        }
    }

    /// Does `self` fit within `budget` at the given utilization cap
    /// (routable designs stay below ~85% utilization)?
    pub fn fits_in(&self, budget: &Self, util_cap: f64) -> bool {
        self.luts <= budget.luts * util_cap
            && self.ffs <= budget.ffs * util_cap
            && self.dsps <= budget.dsps * util_cap
            && self.ram_kb <= budget.ram_kb * util_cap
    }

    /// Highest utilization fraction across resource classes.
    pub fn utilization_vs(&self, budget: &Self) -> f64 {
        [
            self.luts / budget.luts.max(1.0),
            self.ffs / budget.ffs.max(1.0),
            self.dsps / budget.dsps.max(1.0),
            self.ram_kb / budget.ram_kb.max(1.0),
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

/// Per-operation resource cost table for one fully-pipelined lane (II=1).
/// Numbers are representative of single-precision OpenCL-HLS results on
/// Arria-10-class parts: an fp add ≈ 700 LUTs, an fp mul ≈ 1 DSP + glue, a
/// divide ≈ 4 DSPs + heavy logic, sin/cos/sqrt cores ≈ 8 DSPs and several
/// thousand LUTs.
#[derive(Debug, Clone, Copy)]
pub struct OpCosts {
    /// LUTs per float add/sub.
    pub lut_per_fadd: f64,
    /// LUTs of glue per float multiply.
    pub lut_per_fmul: f64,
    /// DSPs per float multiply.
    pub dsp_per_fmul: f64,
    /// DSPs per float divide.
    pub dsp_per_fdiv: f64,
    /// LUTs per float divide.
    pub lut_per_fdiv: f64,
    /// DSPs per special-function core.
    pub dsp_per_special: f64,
    /// LUTs per special-function core.
    pub lut_per_special: f64,
    /// LUTs per integer op.
    pub lut_per_iop: f64,
    /// LUTs per memory port (load/store unit).
    pub lut_per_memport: f64,
    /// RAM kB per memory port (burst buffers).
    pub ram_kb_per_memport: f64,
    /// Fixed control overhead per kernel, LUTs.
    pub lut_fixed: f64,
    /// FF-to-LUT ratio of pipelined designs.
    pub ff_per_lut: f64,
}

impl Default for OpCosts {
    fn default() -> Self {
        Self {
            lut_per_fadd: 700.0,
            lut_per_fmul: 150.0,
            dsp_per_fmul: 1.0,
            dsp_per_fdiv: 4.0,
            lut_per_fdiv: 3000.0,
            dsp_per_special: 8.0,
            lut_per_special: 4500.0,
            lut_per_iop: 60.0,
            lut_per_memport: 900.0,
            ram_kb_per_memport: 18.0,
            lut_fixed: 12_000.0,
            ff_per_lut: 1.6,
        }
    }
}

/// Estimate the resources of ONE pipeline lane implementing the loop body
/// described by `census` (the mid-compile report of the paper's §3.2).
pub fn estimate_lane(census: &OpCensus, costs: &OpCosts) -> FpgaResources {
    let luts = costs.lut_fixed
        + census.fadd as f64 * costs.lut_per_fadd
        + census.fmul as f64 * costs.lut_per_fmul
        + census.fdiv as f64 * costs.lut_per_fdiv
        + census.fspecial as f64 * costs.lut_per_special
        + census.iops as f64 * costs.lut_per_iop
        + (census.loads + census.stores) as f64 * costs.lut_per_memport;
    let dsps = census.fmul as f64 * costs.dsp_per_fmul
        + census.fdiv as f64 * costs.dsp_per_fdiv
        + census.fspecial as f64 * costs.dsp_per_special;
    let ram = (census.loads + census.stores) as f64 * costs.ram_kb_per_memport;
    FpgaResources {
        luts,
        ffs: luts * costs.ff_per_lut,
        dsps,
        ram_kb: ram,
    }
}

/// One simulated server of the production cluster: job-slot capacity per
/// destination kind plus the idle draws the fleet scheduler charges while
/// the node is powered on.
///
/// A *slot* is one concurrently-runnable job: a `Cpu` slot is the host
/// running an unoffloaded (all-CPU) deployment, the accelerator slots are
/// exclusive device reservations. Idle draws are split between the chassis
/// (always charged while the node is on) and per-accelerator extras
/// (charged only while the device is powered on but idle — and power-gated
/// away after [`crate::power::IdlePolicy::gate_after_s`]).
#[derive(Debug, Clone)]
pub struct NodeSpec {
    /// Node name (reports).
    pub name: String,
    /// Whole-chassis idle draw, Watts (server + installed devices at
    /// rest — the Fig. 4 testbed's ≈105 W for an R740 + PAC).
    pub chassis_idle_w: f64,
    /// Concurrent all-CPU jobs the host runs.
    pub host_slots: usize,
    /// GPU job slots.
    pub gpu_slots: usize,
    /// FPGA job slots.
    pub fpga_slots: usize,
    /// Many-core CPU job slots.
    pub manycore_slots: usize,
    /// Extra GPU draw while powered on but idle, Watts (beyond the
    /// chassis figure).
    pub gpu_idle_w: f64,
    /// Extra FPGA idle draw, Watts.
    pub fpga_idle_w: f64,
    /// Extra many-core idle draw, Watts.
    pub manycore_idle_w: f64,
}

impl NodeSpec {
    /// The paper's testbed server as a cluster node: one job slot per
    /// destination. The measured 105 W chassis idle already includes the
    /// installed accelerators at rest (Fig. 5's baseline), so the
    /// per-accelerator idle extras are zero here.
    pub fn r740_pac(name: &str) -> Self {
        Self {
            name: name.to_string(),
            chassis_idle_w: 105.0,
            host_slots: 1,
            gpu_slots: 1,
            fpga_slots: 1,
            manycore_slots: 1,
            gpu_idle_w: 0.0,
            fpga_idle_w: 0.0,
            manycore_idle_w: 0.0,
        }
    }

    /// A GPU-dense node whose accelerators are *not* folded into the
    /// chassis idle figure — each powered-on idle GPU adds its own draw,
    /// which the scheduler's gating policy can save.
    pub fn gpu_box(name: &str) -> Self {
        Self {
            name: name.to_string(),
            chassis_idle_w: 90.0,
            host_slots: 1,
            gpu_slots: 2,
            fpga_slots: 0,
            manycore_slots: 0,
            gpu_idle_w: 12.0,
            fpga_idle_w: 0.0,
            manycore_idle_w: 0.0,
        }
    }

    /// Job slots this node offers for a destination kind.
    pub fn slots(&self, kind: DeviceKind) -> usize {
        match kind {
            DeviceKind::Cpu => self.host_slots,
            DeviceKind::Gpu => self.gpu_slots,
            DeviceKind::Fpga => self.fpga_slots,
            DeviceKind::ManyCore => self.manycore_slots,
        }
    }

    /// Powered-on-but-idle draw of one slot of `kind`, Watts. Host slots
    /// draw nothing beyond the chassis idle.
    pub fn slot_idle_w(&self, kind: DeviceKind) -> f64 {
        match kind {
            DeviceKind::Cpu => 0.0,
            DeviceKind::Gpu => self.gpu_idle_w,
            DeviceKind::Fpga => self.fpga_idle_w,
            DeviceKind::ManyCore => self.manycore_idle_w,
        }
    }
}

/// Live slot occupancy of one [`NodeSpec`] — the admission controller's
/// view of what is free. Slots of a kind are indexed `0..slots(kind)` and
/// acquired lowest-index-first so per-slot busy intervals (the idle-energy
/// ledger's input) are deterministic.
#[derive(Debug, Clone)]
pub struct NodeOccupancy {
    spec: NodeSpec,
    busy: [Vec<bool>; 4],
}

/// Dense index for per-kind bookkeeping.
fn kind_idx(kind: DeviceKind) -> usize {
    match kind {
        DeviceKind::Cpu => 0,
        DeviceKind::ManyCore => 1,
        DeviceKind::Gpu => 2,
        DeviceKind::Fpga => 3,
    }
}

impl NodeOccupancy {
    /// All slots free.
    pub fn new(spec: NodeSpec) -> Self {
        let busy = [
            vec![false; spec.slots(DeviceKind::Cpu)],
            vec![false; spec.slots(DeviceKind::ManyCore)],
            vec![false; spec.slots(DeviceKind::Gpu)],
            vec![false; spec.slots(DeviceKind::Fpga)],
        ];
        Self { spec, busy }
    }

    /// The node description.
    pub fn spec(&self) -> &NodeSpec {
        &self.spec
    }

    /// Free slots of a kind.
    pub fn free(&self, kind: DeviceKind) -> usize {
        self.busy[kind_idx(kind)].iter().filter(|b| !**b).count()
    }

    /// Busy slots of a kind.
    pub fn in_use(&self, kind: DeviceKind) -> usize {
        self.busy[kind_idx(kind)].iter().filter(|b| **b).count()
    }

    /// Reserve the lowest-index free slot of a kind; `None` when full.
    pub fn acquire(&mut self, kind: DeviceKind) -> Option<usize> {
        let slots = &mut self.busy[kind_idx(kind)];
        let idx = slots.iter().position(|b| !*b)?;
        slots[idx] = true;
        Some(idx)
    }

    /// Release a previously acquired slot.
    pub fn release(&mut self, kind: DeviceKind, slot: usize) {
        let slots = &mut self.busy[kind_idx(kind)];
        assert!(slots[slot], "releasing a free slot");
        slots[slot] = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn census(fadd: u64, fmul: u64, fspecial: u64, mem: u64) -> OpCensus {
        OpCensus {
            fadd,
            fmul,
            fdiv: 0,
            fspecial,
            iops: 2,
            loads: mem,
            stores: 1,
            calls: 0,
        }
    }

    #[test]
    fn bigger_bodies_cost_more() {
        let costs = OpCosts::default();
        let small = estimate_lane(&census(1, 1, 0, 1), &costs);
        let big = estimate_lane(&census(8, 8, 4, 6), &costs);
        assert!(big.luts > small.luts);
        assert!(big.dsps > small.dsps);
        assert!(big.ram_kb > small.ram_kb);
    }

    #[test]
    fn specials_dominate_dsp_usage() {
        let costs = OpCosts::default();
        let r = estimate_lane(&census(2, 3, 2, 2), &costs);
        assert_eq!(r.dsps, 3.0 + 16.0);
    }

    #[test]
    fn fits_in_respects_cap() {
        let budget = FpgaResources::arria10_gx();
        let half = budget.scale(0.5);
        let near = budget.scale(0.86);
        assert!(half.fits_in(&budget, 0.85));
        assert!(!near.fits_in(&budget, 0.85));
    }

    #[test]
    fn utilization_reports_max_class() {
        let budget = FpgaResources::arria10_gx();
        let mut r = budget.scale(0.1);
        r.dsps = budget.dsps * 0.7;
        assert!((r.utilization_vs(&budget) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn mriq_like_body_fits_arria10() {
        // computeQ inner body: ~5 adds, ~6 muls, 2 specials, 4 mem ports.
        let costs = OpCosts::default();
        let lane = estimate_lane(&census(5, 6, 2, 4), &costs);
        assert!(lane.fits_in(&FpgaResources::arria10_gx(), 0.85));
        // And several replicated lanes still fit.
        assert!(lane.scale(4.0).fits_in(&FpgaResources::arria10_gx(), 0.85));
    }

    #[test]
    fn r740_pac_node_offers_one_slot_per_destination() {
        let n = NodeSpec::r740_pac("node0");
        for kind in [
            DeviceKind::Cpu,
            DeviceKind::Gpu,
            DeviceKind::Fpga,
            DeviceKind::ManyCore,
        ] {
            assert_eq!(n.slots(kind), 1, "{kind}");
        }
        // The 105 W chassis figure already covers installed idle devices.
        assert_eq!(n.chassis_idle_w, 105.0);
        assert_eq!(n.slot_idle_w(DeviceKind::Fpga), 0.0);
        assert_eq!(n.slot_idle_w(DeviceKind::Cpu), 0.0);
    }

    #[test]
    fn occupancy_acquires_lowest_free_slot_first() {
        let mut occ = NodeOccupancy::new(NodeSpec::gpu_box("g0"));
        assert_eq!(occ.free(DeviceKind::Gpu), 2);
        assert_eq!(occ.acquire(DeviceKind::Gpu), Some(0));
        assert_eq!(occ.acquire(DeviceKind::Gpu), Some(1));
        assert_eq!(occ.acquire(DeviceKind::Gpu), None, "node full");
        assert_eq!(occ.in_use(DeviceKind::Gpu), 2);
        occ.release(DeviceKind::Gpu, 0);
        assert_eq!(occ.acquire(DeviceKind::Gpu), Some(0), "lowest index reused");
        // A gpu_box has no FPGA slots at all.
        assert_eq!(occ.free(DeviceKind::Fpga), 0);
        assert_eq!(occ.acquire(DeviceKind::Fpga), None);
    }

    #[test]
    #[should_panic(expected = "releasing a free slot")]
    fn releasing_a_free_slot_panics() {
        let mut occ = NodeOccupancy::new(NodeSpec::r740_pac("n"));
        occ.release(DeviceKind::Gpu, 0);
    }
}
