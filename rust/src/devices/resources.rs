//! FPGA resource accounting — Flip-Flops, Lookup Tables, DSP blocks and
//! on-chip RAM. The paper's §3.2 narrows FPGA candidates by *precompiling*
//! OpenCL and reading the reported resource usage ("the resources such as
//! Flip Flop and Lookup Table to be created are known in the middle of
//! compilation"); [`estimate_lane`] is the analytic stand-in for that
//! mid-compile report.

use crate::canalyze::OpCensus;

/// Resource vector of an FPGA design (or budget of a part).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FpgaResources {
    /// Adaptive logic lookup tables.
    pub luts: f64,
    /// Flip-flops / registers.
    pub ffs: f64,
    /// DSP blocks (hard multipliers).
    pub dsps: f64,
    /// On-chip RAM, kilobytes.
    pub ram_kb: f64,
}

impl FpgaResources {
    /// Intel Arria 10 GX 1150 (the paper's Intel PAC card), minus the
    /// board-support-package share the Acceleration Stack reserves.
    pub fn arria10_gx() -> Self {
        Self {
            luts: 1_150_000.0 * 0.75,
            ffs: 1_708_800.0 * 0.75,
            dsps: 1_518.0 * 0.9,
            ram_kb: 53_000.0 * 0.8,
        }
    }

    /// Scale by a replication factor (pipeline lanes).
    pub fn scale(&self, k: f64) -> Self {
        Self {
            luts: self.luts * k,
            ffs: self.ffs * k,
            dsps: self.dsps * k,
            ram_kb: self.ram_kb * k,
        }
    }

    /// Component-wise addition.
    pub fn plus(&self, other: &Self) -> Self {
        Self {
            luts: self.luts + other.luts,
            ffs: self.ffs + other.ffs,
            dsps: self.dsps + other.dsps,
            ram_kb: self.ram_kb + other.ram_kb,
        }
    }

    /// Does `self` fit within `budget` at the given utilization cap
    /// (routable designs stay below ~85% utilization)?
    pub fn fits_in(&self, budget: &Self, util_cap: f64) -> bool {
        self.luts <= budget.luts * util_cap
            && self.ffs <= budget.ffs * util_cap
            && self.dsps <= budget.dsps * util_cap
            && self.ram_kb <= budget.ram_kb * util_cap
    }

    /// Highest utilization fraction across resource classes.
    pub fn utilization_vs(&self, budget: &Self) -> f64 {
        [
            self.luts / budget.luts.max(1.0),
            self.ffs / budget.ffs.max(1.0),
            self.dsps / budget.dsps.max(1.0),
            self.ram_kb / budget.ram_kb.max(1.0),
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

/// Per-operation resource cost table for one fully-pipelined lane (II=1).
/// Numbers are representative of single-precision OpenCL-HLS results on
/// Arria-10-class parts: an fp add ≈ 700 LUTs, an fp mul ≈ 1 DSP + glue, a
/// divide ≈ 4 DSPs + heavy logic, sin/cos/sqrt cores ≈ 8 DSPs and several
/// thousand LUTs.
#[derive(Debug, Clone, Copy)]
pub struct OpCosts {
    /// LUTs per float add/sub.
    pub lut_per_fadd: f64,
    /// LUTs of glue per float multiply.
    pub lut_per_fmul: f64,
    /// DSPs per float multiply.
    pub dsp_per_fmul: f64,
    /// DSPs per float divide.
    pub dsp_per_fdiv: f64,
    /// LUTs per float divide.
    pub lut_per_fdiv: f64,
    /// DSPs per special-function core.
    pub dsp_per_special: f64,
    /// LUTs per special-function core.
    pub lut_per_special: f64,
    /// LUTs per integer op.
    pub lut_per_iop: f64,
    /// LUTs per memory port (load/store unit).
    pub lut_per_memport: f64,
    /// RAM kB per memory port (burst buffers).
    pub ram_kb_per_memport: f64,
    /// Fixed control overhead per kernel, LUTs.
    pub lut_fixed: f64,
    /// FF-to-LUT ratio of pipelined designs.
    pub ff_per_lut: f64,
}

impl Default for OpCosts {
    fn default() -> Self {
        Self {
            lut_per_fadd: 700.0,
            lut_per_fmul: 150.0,
            dsp_per_fmul: 1.0,
            dsp_per_fdiv: 4.0,
            lut_per_fdiv: 3000.0,
            dsp_per_special: 8.0,
            lut_per_special: 4500.0,
            lut_per_iop: 60.0,
            lut_per_memport: 900.0,
            ram_kb_per_memport: 18.0,
            lut_fixed: 12_000.0,
            ff_per_lut: 1.6,
        }
    }
}

/// Estimate the resources of ONE pipeline lane implementing the loop body
/// described by `census` (the mid-compile report of the paper's §3.2).
pub fn estimate_lane(census: &OpCensus, costs: &OpCosts) -> FpgaResources {
    let luts = costs.lut_fixed
        + census.fadd as f64 * costs.lut_per_fadd
        + census.fmul as f64 * costs.lut_per_fmul
        + census.fdiv as f64 * costs.lut_per_fdiv
        + census.fspecial as f64 * costs.lut_per_special
        + census.iops as f64 * costs.lut_per_iop
        + (census.loads + census.stores) as f64 * costs.lut_per_memport;
    let dsps = census.fmul as f64 * costs.dsp_per_fmul
        + census.fdiv as f64 * costs.dsp_per_fdiv
        + census.fspecial as f64 * costs.dsp_per_special;
    let ram = (census.loads + census.stores) as f64 * costs.ram_kb_per_memport;
    FpgaResources {
        luts,
        ffs: luts * costs.ff_per_lut,
        dsps,
        ram_kb: ram,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn census(fadd: u64, fmul: u64, fspecial: u64, mem: u64) -> OpCensus {
        OpCensus {
            fadd,
            fmul,
            fdiv: 0,
            fspecial,
            iops: 2,
            loads: mem,
            stores: 1,
            calls: 0,
        }
    }

    #[test]
    fn bigger_bodies_cost_more() {
        let costs = OpCosts::default();
        let small = estimate_lane(&census(1, 1, 0, 1), &costs);
        let big = estimate_lane(&census(8, 8, 4, 6), &costs);
        assert!(big.luts > small.luts);
        assert!(big.dsps > small.dsps);
        assert!(big.ram_kb > small.ram_kb);
    }

    #[test]
    fn specials_dominate_dsp_usage() {
        let costs = OpCosts::default();
        let r = estimate_lane(&census(2, 3, 2, 2), &costs);
        assert_eq!(r.dsps, 3.0 + 16.0);
    }

    #[test]
    fn fits_in_respects_cap() {
        let budget = FpgaResources::arria10_gx();
        let half = budget.scale(0.5);
        let near = budget.scale(0.86);
        assert!(half.fits_in(&budget, 0.85));
        assert!(!near.fits_in(&budget, 0.85));
    }

    #[test]
    fn utilization_reports_max_class() {
        let budget = FpgaResources::arria10_gx();
        let mut r = budget.scale(0.1);
        r.dsps = budget.dsps * 0.7;
        assert!((r.utilization_vs(&budget) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn mriq_like_body_fits_arria10() {
        // computeQ inner body: ~5 adds, ~6 muls, 2 specials, 4 mem ports.
        let costs = OpCosts::default();
        let lane = estimate_lane(&census(5, 6, 2, 4), &costs);
        assert!(lane.fits_in(&FpgaResources::arria10_gx(), 0.85));
        // And several replicated lanes still fit.
        assert!(lane.scale(4.0).fits_in(&FpgaResources::arria10_gx(), 0.85));
    }
}
