//! GPU model (CUDA/OpenACC migration destination).
//!
//! Captures the decision landscape that drives the paper's GA (§3.1):
//! compute-dense loops with few launches win big; transfer-dominated or
//! launch-dominated patterns *lose* to the CPU — which is why naive
//! automatic parallelization fails and measurement-driven search is needed.

use super::cpu::CpuModel;
use super::traits::{Accelerator, DeviceKind, KernelEstimate, NestWork, TransferMode};

/// GPU device model.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// Host model (for reference scaling).
    pub host: CpuModel,
    /// Effective weighted-FLOP throughput, FLOP/s.
    pub gflops: f64,
    /// Device memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// PCIe effective bandwidth, bytes/s.
    pub pcie_bw: f64,
    /// Per-transfer-event fixed latency, seconds.
    pub pcie_latency_s: f64,
    /// Kernel launch overhead, seconds per launch.
    pub launch_s: f64,
    /// Extra draw while a kernel runs, Watts.
    pub active_w: f64,
    /// Host draw while driving the GPU (driver/polling), Watts.
    pub host_drive_w: f64,
    /// Idle draw added to the server baseline while installed, Watts.
    pub idle_extra_w: f64,
}

impl GpuModel {
    /// Mid-range datacenter GPU calibrated against [`CpuModel::r740`]:
    /// ≈10× on compute-dense nests before transfer costs, at a high
    /// active draw. On MRI-Q this makes the GPU the *fastest* destination
    /// but the FPGA the best *power-aware* one — the §3.3 selection
    /// landscape this paper adds over the time-only previous method.
    pub fn tesla() -> Self {
        Self {
            host: CpuModel::r740(),
            gflops: 10.0e9,
            mem_bw: 300.0e9,
            pcie_bw: 8.0e9,
            pcie_latency_s: 20.0e-6,
            launch_s: 15.0e-6,
            active_w: 120.0,
            host_drive_w: 8.0,
            idle_extra_w: 0.0,
        }
    }
}

impl Accelerator for GpuModel {
    fn kind(&self) -> DeviceKind {
        DeviceKind::Gpu
    }

    fn supports(&self, _work: &NestWork) -> Result<(), String> {
        Ok(())
    }

    fn estimate(&self, w: &NestWork, xfer: TransferMode) -> KernelEstimate {
        let compute = (w.flops / self.gflops).max(w.bytes / self.mem_bw);
        // §3.1 transfer optimization: batched mode moves the payload once
        // per run; naive per-entry mode pays it at every kernel entry.
        let events = match xfer {
            TransferMode::Batched => 1.0,
            TransferMode::PerEntry => w.entries.max(1.0),
        };
        // In and out.
        let transfer =
            events * (2.0 * w.transfer_bytes / self.pcie_bw + 2.0 * self.pcie_latency_s);
        KernelEstimate {
            compute_s: compute,
            transfer_s: transfer,
            launch_s: self.launch_s * w.entries.max(1.0),
            dyn_power_w: self.active_w,
            host_power_w: self.host_drive_w,
        }
    }

    fn prep_latency_s(&self, _work: &NestWork) -> f64 {
        // OpenACC/CUDA compile of one pattern.
        90.0
    }

    fn idle_w(&self) -> f64 {
        self.idle_extra_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::canalyze::OpCensus;

    fn work(flops: f64, transfer: f64, entries: f64) -> NestWork {
        NestWork {
            flops,
            bytes: flops * 0.5,
            transfer_bytes: transfer,
            entries,
            trips: 1.0e6,
            census: OpCensus::default(),
        }
    }

    #[test]
    fn compute_dense_nest_beats_cpu() {
        let gpu = GpuModel::tesla();
        let w = work(1.0e10, 4.0e6, 1.0);
        let cpu_t = gpu.host.nest_time_s(&w);
        let gpu_t = gpu.estimate(&w, TransferMode::Batched).total_s();
        assert!(cpu_t / gpu_t > 5.0, "speedup {}", cpu_t / gpu_t);
    }

    #[test]
    fn transfer_dominated_nest_loses_to_cpu() {
        let gpu = GpuModel::tesla();
        // Tiny compute, large payload (the vecadd case).
        let w = work(1.0e5, 64.0e6, 1.0);
        let cpu_t = gpu.host.nest_time_s(&w);
        let gpu_t = gpu.estimate(&w, TransferMode::Batched).total_s();
        assert!(gpu_t > cpu_t, "gpu {gpu_t} vs cpu {cpu_t}");
    }

    #[test]
    fn batching_beats_per_entry_transfers() {
        let gpu = GpuModel::tesla();
        let w = work(1.0e9, 4.0e6, 500.0);
        let naive = gpu.estimate(&w, TransferMode::PerEntry);
        let batched = gpu.estimate(&w, TransferMode::Batched);
        assert!(naive.transfer_s > 100.0 * batched.transfer_s);
    }

    #[test]
    fn launch_overhead_scales_with_entries() {
        let gpu = GpuModel::tesla();
        let few = gpu.estimate(&work(1.0e9, 1.0e6, 2.0), TransferMode::Batched);
        let many = gpu.estimate(&work(1.0e9, 1.0e6, 2000.0), TransferMode::Batched);
        assert!(many.launch_s > few.launch_s * 500.0);
    }
}
