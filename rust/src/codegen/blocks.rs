//! Function-block substitution for the code generators: replace a
//! detected block's root loop nest with a call into the chosen device's
//! library / IP-core implementation (cuBLAS/cuFFT on the GPU path,
//! `enadapt_ip_*` cores on the FPGA host program, CBLAS/FFTW on the
//! many-core path), composing with the per-loop annotators via
//! [`WithBlocks`].

use super::emit::{Annotator, LoopAnnotation};
use crate::canalyze::{Analysis, LoopId};
use crate::devices::DeviceKind;
use crate::verifier::AppModel;

/// One block substitution: the loop to replace and the emitted call.
#[derive(Debug, Clone)]
pub struct BlockSub {
    /// Root loop of the substituted nest.
    pub root: LoopId,
    /// Replacement lines (comment + library call).
    pub lines: Vec<String>,
}

/// Build the substitutions for a plan's active blocks on a destination.
/// Blocks without an implementation on `device` are skipped (the
/// verifier fails such plans before codegen runs).
pub fn substitutions(
    an: &Analysis,
    app: &AppModel,
    bits: &[bool],
    device: DeviceKind,
) -> Vec<BlockSub> {
    app.active_blocks(bits)
        .into_iter()
        .filter_map(|bi| sub_for(an, app, bi, device))
        .collect()
}

/// Like [`substitutions`], but for a mixed-destination plan: each active
/// block is substituted with the library call of **its own** destination
/// gene (`dests` is the full per-gene device vector, loops first).
pub fn substitutions_mixed(
    an: &Analysis,
    app: &AppModel,
    dests: &[DeviceKind],
) -> Vec<BlockSub> {
    let bits: Vec<bool> = dests.iter().map(|&d| d != DeviceKind::Cpu).collect();
    let n_loops = app.candidates.len();
    app.active_blocks(&bits)
        .into_iter()
        .filter_map(|bi| sub_for(an, app, bi, dests[n_loops + bi]))
        .collect()
}

/// The substitution of one active block on one device, if implemented.
fn sub_for(an: &Analysis, app: &AppModel, bi: usize, device: DeviceKind) -> Option<BlockSub> {
    let bw = &app.blocks[bi];
    let im = app.block_impl(bi, device)?;
    let info = &an.loops[bw.detected.root.0];
    // Outputs first, then inputs, then the in-scalars (sizes).
    let mut args: Vec<String> = info.arrays_written.iter().cloned().collect();
    args.extend(
        info.arrays_read
            .iter()
            .filter(|a| !info.arrays_written.contains(*a))
            .cloned(),
    );
    args.extend(info.scalars_in.iter().cloned());
    Some(BlockSub {
        root: bw.detected.root,
        lines: vec![
            format!(
                "/* enadapt: {} block in {} (line {}) -> {} */",
                bw.detected.kind, bw.detected.func, bw.detected.line, im.library
            ),
            format!("{}({});", im.call_symbol, args.join(", ")),
        ],
    })
}

/// Annotator combinator: block roots are replaced with their library
/// call; every other loop defers to the wrapped per-loop annotator.
pub struct WithBlocks<'a> {
    inner: &'a dyn Annotator,
    subs: &'a [BlockSub],
}

impl<'a> WithBlocks<'a> {
    /// Wrap `inner`, substituting `subs`.
    pub fn new(inner: &'a dyn Annotator, subs: &'a [BlockSub]) -> Self {
        Self { inner, subs }
    }
}

impl Annotator for WithBlocks<'_> {
    fn prelude(&self) -> Vec<String> {
        let mut p = self.inner.prelude();
        if !self.subs.is_empty() {
            p.push(format!(
                "/* enadapt: {} function block(s) substituted with device library calls */",
                self.subs.len()
            ));
        }
        p
    }

    fn annotate(&self, loop_id: usize) -> Option<LoopAnnotation> {
        if let Some(s) = self.subs.iter().find(|s| s.root.0 == loop_id) {
            return Some(LoopAnnotation {
                before: vec![],
                after: vec![],
                replace: Some(s.lines.clone()),
            });
        }
        self.inner.annotate(loop_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::canalyze::analyze_source;
    use crate::codegen::openacc;
    use crate::devices::CpuModel;
    use crate::funcblock::BlockDb;
    use crate::workloads;

    fn gemm_app() -> (Analysis, AppModel) {
        let an = analyze_source("gemm.c", workloads::GEMM_C).unwrap();
        let app = AppModel::from_analysis_with_blocks(
            &an,
            &CpuModel::r740(),
            14.0,
            &BlockDb::standard(),
        )
        .unwrap();
        (an, app)
    }

    #[test]
    fn gpu_substitution_emits_cublas_call() {
        let (an, app) = gemm_app();
        let mut bits = vec![false; app.genome_len()];
        *bits.last_mut().unwrap() = true;
        let subs = substitutions(&an, &app, &bits, DeviceKind::Gpu);
        assert_eq!(subs.len(), 1);
        let text = openacc::generate_with_blocks(
            &an,
            &[],
            crate::devices::TransferMode::Batched,
            &subs,
        );
        assert!(text.contains("cublasSgemm("), "{text}");
        assert!(text.contains("matmul block"), "{text}");
        // The naive triple loop is gone from gemm() — main's loops stay.
        let gemm_fn = text.split("void gemm").nth(1).unwrap().split("int main").next().unwrap();
        assert!(!gemm_fn.contains("for ("), "{gemm_fn}");
    }

    #[test]
    fn inactive_blocks_change_nothing() {
        let (an, app) = gemm_app();
        let bits = vec![false; app.genome_len()];
        assert!(substitutions(&an, &app, &bits, DeviceKind::Gpu).is_empty());
        let with = openacc::generate_with_blocks(
            &an,
            &[],
            crate::devices::TransferMode::Batched,
            &[],
        );
        let plain = openacc::generate(&an, &[], crate::devices::TransferMode::Batched);
        assert_eq!(with, plain, "empty substitution list is the identity");
    }
}
