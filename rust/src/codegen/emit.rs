//! C pretty-printer for the analyzed AST with per-loop annotation hooks —
//! the backbone of the paper's *automatic code conversion* (Step 3 output):
//! the OpenACC / OpenMP / OpenCL generators all re-emit the program with
//! directives or kernel extractions inserted at chosen loop statements.

use crate::canalyze::ast::*;

/// Text inserted around a loop statement.
#[derive(Debug, Clone, Default)]
pub struct LoopAnnotation {
    /// Lines emitted immediately before the loop (e.g. a pragma).
    pub before: Vec<String>,
    /// Lines emitted immediately after the loop.
    pub after: Vec<String>,
    /// Replace the loop entirely with these lines (OpenCL host-side call).
    pub replace: Option<Vec<String>>,
}

/// Annotation provider keyed by loop id.
pub trait Annotator {
    /// Annotation for `loop_id` (None = emit unchanged).
    fn annotate(&self, loop_id: usize) -> Option<LoopAnnotation>;

    /// Lines prepended to the whole file (headers, kernel externs).
    fn prelude(&self) -> Vec<String> {
        Vec::new()
    }
}

/// No-op annotator: plain round-trip printing.
pub struct Plain;

impl Annotator for Plain {
    fn annotate(&self, _loop_id: usize) -> Option<LoopAnnotation> {
        None
    }
}

/// Render a whole program.
pub fn emit_program(prog: &Program, ann: &dyn Annotator) -> String {
    let mut out = String::new();
    for line in ann.prelude() {
        out.push_str(&line);
        out.push('\n');
    }
    if !ann.prelude().is_empty() {
        out.push('\n');
    }
    for (i, f) in prog.functions.iter().enumerate() {
        if i > 0 {
            out.push('\n');
        }
        emit_function(&mut out, f, ann);
    }
    out
}

fn ty_name(ty: Ty) -> &'static str {
    match ty {
        Ty::Int => "int",
        Ty::Float => "float",
        Ty::Void => "void",
    }
}

fn emit_function(out: &mut String, f: &Function, ann: &dyn Annotator) {
    out.push_str(ty_name(f.ret));
    out.push(' ');
    out.push_str(&f.name);
    out.push('(');
    for (i, p) in f.params.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(ty_name(p.ty));
        out.push(' ');
        if p.is_array {
            out.push('*');
        }
        out.push_str(&p.name);
    }
    out.push_str(") {\n");
    emit_block(out, &f.body, 1, ann);
    out.push_str("}\n");
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn emit_block(out: &mut String, body: &[Stmt], depth: usize, ann: &dyn Annotator) {
    for s in body {
        emit_stmt(out, s, depth, ann);
    }
}

fn emit_stmt(out: &mut String, s: &Stmt, depth: usize, ann: &dyn Annotator) {
    match s {
        Stmt::Decl { ty, name, init, .. } => {
            indent(out, depth);
            out.push_str(ty_name(*ty));
            out.push(' ');
            out.push_str(name);
            if let Some(e) = init {
                out.push_str(" = ");
                emit_expr(out, e);
            }
            out.push_str(";\n");
        }
        Stmt::ArrayDecl { ty, name, size, .. } => {
            indent(out, depth);
            out.push_str(ty_name(*ty));
            out.push(' ');
            out.push_str(name);
            out.push('[');
            emit_expr(out, size);
            out.push_str("];\n");
        }
        Stmt::Assign { lv, op, rhs, .. } => {
            indent(out, depth);
            emit_lvalue(out, lv);
            out.push_str(match op {
                AssignOp::Set => " = ",
                AssignOp::Add => " += ",
                AssignOp::Sub => " -= ",
                AssignOp::Mul => " *= ",
                AssignOp::Div => " /= ",
            });
            emit_expr(out, rhs);
            out.push_str(";\n");
        }
        Stmt::For {
            loop_id,
            init,
            cond,
            step,
            body,
            ..
        } => {
            let annotation = ann.annotate(*loop_id).unwrap_or_default();
            if let Some(replacement) = &annotation.replace {
                for line in replacement {
                    indent(out, depth);
                    out.push_str(line);
                    out.push('\n');
                }
                return;
            }
            for line in &annotation.before {
                indent(out, depth);
                out.push_str(line);
                out.push('\n');
            }
            indent(out, depth);
            out.push_str("for (");
            if let Some(st) = init.as_deref() {
                emit_stmt_inline(out, st);
            }
            out.push_str("; ");
            emit_expr(out, cond);
            out.push_str("; ");
            if let Some(st) = step.as_deref() {
                emit_stmt_inline(out, st);
            }
            out.push_str(") {\n");
            emit_block(out, body, depth + 1, ann);
            indent(out, depth);
            out.push_str("}\n");
            for line in &annotation.after {
                indent(out, depth);
                out.push_str(line);
                out.push('\n');
            }
        }
        Stmt::While { cond, body, .. } => {
            indent(out, depth);
            out.push_str("while (");
            emit_expr(out, cond);
            out.push_str(") {\n");
            emit_block(out, body, depth + 1, ann);
            indent(out, depth);
            out.push_str("}\n");
        }
        Stmt::If { cond, then, otherwise, .. } => {
            indent(out, depth);
            out.push_str("if (");
            emit_expr(out, cond);
            out.push_str(") {\n");
            emit_block(out, then, depth + 1, ann);
            indent(out, depth);
            out.push('}');
            if otherwise.is_empty() {
                out.push('\n');
            } else {
                out.push_str(" else {\n");
                emit_block(out, otherwise, depth + 1, ann);
                indent(out, depth);
                out.push_str("}\n");
            }
        }
        Stmt::Return(e, _) => {
            indent(out, depth);
            out.push_str("return");
            if let Some(e) = e {
                out.push(' ');
                emit_expr(out, e);
            }
            out.push_str(";\n");
        }
        Stmt::ExprStmt(e, _) => {
            indent(out, depth);
            emit_expr(out, e);
            out.push_str(";\n");
        }
        Stmt::Break(_) => {
            indent(out, depth);
            out.push_str("break;\n");
        }
        Stmt::Continue(_) => {
            indent(out, depth);
            out.push_str("continue;\n");
        }
    }
}

/// `for`-header fragments: no indent, no trailing `;`.
fn emit_stmt_inline(out: &mut String, s: &Stmt) {
    match s {
        Stmt::Decl { ty, name, init, .. } => {
            out.push_str(ty_name(*ty));
            out.push(' ');
            out.push_str(name);
            if let Some(e) = init {
                out.push_str(" = ");
                emit_expr(out, e);
            }
        }
        Stmt::Assign { lv, op, rhs, .. } => {
            emit_lvalue(out, lv);
            out.push_str(match op {
                AssignOp::Set => " = ",
                AssignOp::Add => " += ",
                AssignOp::Sub => " -= ",
                AssignOp::Mul => " *= ",
                AssignOp::Div => " /= ",
            });
            emit_expr(out, rhs);
        }
        other => panic!("statement kind not valid in for-header: {other:?}"),
    }
}

fn emit_lvalue(out: &mut String, lv: &LValue) {
    match lv {
        LValue::Var(n) => out.push_str(n),
        LValue::Index(n, idx) => {
            out.push_str(n);
            out.push('[');
            emit_expr(out, idx);
            out.push(']');
        }
    }
}

/// Emit an expression (fully parenthesized for associativity safety).
pub fn emit_expr(out: &mut String, e: &Expr) {
    match e {
        Expr::IntLit(v, _) => out.push_str(&v.to_string()),
        Expr::FloatLit(v, _) => {
            if *v == v.trunc() && v.abs() < 1e15 {
                out.push_str(&format!("{:.1}f", v));
            } else {
                out.push_str(&format!("{}f", v));
            }
        }
        Expr::StrLit(s, _) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Expr::Var(n, _) => out.push_str(n),
        Expr::Index(n, idx, _) => {
            out.push_str(n);
            out.push('[');
            emit_expr(out, idx);
            out.push(']');
        }
        Expr::Bin(op, a, b, _) => {
            out.push('(');
            emit_expr(out, a);
            out.push_str(match op {
                BinOp::Add => " + ",
                BinOp::Sub => " - ",
                BinOp::Mul => " * ",
                BinOp::Div => " / ",
                BinOp::Mod => " % ",
                BinOp::Lt => " < ",
                BinOp::Le => " <= ",
                BinOp::Gt => " > ",
                BinOp::Ge => " >= ",
                BinOp::Eq => " == ",
                BinOp::Ne => " != ",
                BinOp::And => " && ",
                BinOp::Or => " || ",
            });
            emit_expr(out, b);
            out.push(')');
        }
        Expr::Un(op, a, _) => {
            out.push(match op {
                UnOp::Neg => '-',
                UnOp::Not => '!',
            });
            out.push('(');
            emit_expr(out, a);
            out.push(')');
        }
        Expr::Call(name, args, _) => {
            // Cast intrinsics print back as C casts.
            if name == "__float" || name == "__int" {
                out.push_str(if name == "__float" { "(float)(" } else { "(int)(" });
                emit_expr(out, &args[0]);
                out.push(')');
                return;
            }
            out.push_str(name);
            out.push('(');
            for (i, a) in args.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                emit_expr(out, a);
            }
            out.push(')');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::canalyze::{analyze_source, parser::parse};
    use crate::workloads;

    #[test]
    fn roundtrip_preserves_structure() {
        for (name, src) in workloads::ALL {
            let p1 = parse(name, src).unwrap();
            let text = emit_program(&p1, &Plain);
            let p2 = parse(name, &text).expect("re-parse emitted C");
            assert_eq!(p1.n_loops, p2.n_loops, "{name}: loop count");
            assert_eq!(
                p1.functions.len(),
                p2.functions.len(),
                "{name}: function count"
            );
        }
    }

    #[test]
    fn roundtrip_preserves_semantics() {
        // Profile the original and the re-emitted program: outputs match.
        let an1 = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
        let text = emit_program(&an1.program, &Plain);
        let an2 = analyze_source("mriq2.c", &text).unwrap();
        let o1 = &an1.profile.as_ref().unwrap().printed;
        let o2 = &an2.profile.as_ref().unwrap().printed;
        assert_eq!(o1.len(), o2.len());
        for (a, b) in o1.iter().zip(o2) {
            assert!((a - b).abs() <= 1e-9 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    struct Tag;
    impl Annotator for Tag {
        fn annotate(&self, loop_id: usize) -> Option<LoopAnnotation> {
            (loop_id == 0).then(|| LoopAnnotation {
                before: vec!["#pragma acc kernels".into()],
                after: vec![],
                replace: None,
            })
        }
    }

    #[test]
    fn annotations_are_inserted_before_the_loop() {
        let p = parse(
            "t.c",
            "void f(float *a, int n) { for (int i = 0; i < n; i++) { a[i] = 0.0f; } }",
        )
        .unwrap();
        let text = emit_program(&p, &Tag);
        let pragma_pos = text.find("#pragma acc kernels").unwrap();
        let for_pos = text.find("for (").unwrap();
        assert!(pragma_pos < for_pos);
        // Pragma lines vanish in our preprocessor, so it still re-parses.
        assert!(parse("t.c", &text).is_ok());
    }
}
