//! Automatic code conversion (Step 3 outputs): re-emit the analyzed C with
//! OpenACC directives (GPU), OpenMP pragmas (many-core) or an OpenCL
//! kernel/host split (FPGA) for the offload pattern the search selected.

pub mod emit;
pub mod openacc;
pub mod opencl;
pub mod openmp;

pub use emit::{emit_program, Annotator, LoopAnnotation, Plain};
pub use opencl::OpenClBundle;
