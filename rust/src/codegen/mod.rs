//! Automatic code conversion (Step 3 outputs): re-emit the analyzed C with
//! OpenACC directives (GPU), OpenMP pragmas (many-core) or an OpenCL
//! kernel/host split (FPGA) for the offload pattern the search selected —
//! or, for a mixed-destination plan ([`mixed`], DESIGN.md §15), one
//! output with per-region annotations in each region's own dialect.
//! Function-block substitutions ([`blocks`]) replace a detected block's
//! loop nest with the device library / IP-core call on every path.

pub mod blocks;
pub mod emit;
pub mod mixed;
pub mod openacc;
pub mod opencl;
pub mod openmp;

pub use blocks::{substitutions, substitutions_mixed, BlockSub, WithBlocks};
pub use emit::{emit_program, Annotator, LoopAnnotation, Plain};
pub use opencl::OpenClBundle;
