//! `enadapt` — CLI for the environment-adaptive software coordinator.
//!
//! Subcommands map to the paper's workflow:
//!
//! * `analyze`   — Steps 1–2: loop table + parallelizability report.
//! * `offload`   — Steps 1–7: full power-aware offload job.
//! * `fleet`     — the workload × destination matrix, run concurrently
//!   with a shared cross-job measurement cache.
//! * `sched`     — trace-driven power-budget fleet scheduler: arrivals
//!   packed onto a simulated cluster under a fleet-wide Watt cap, with
//!   drift-triggered re-adaptation (Step 7 in production).
//! * `cache`     — measurement-cache maintenance: fold an append-only
//!   measurement log back into its stable v3 JSON snapshot.
//! * `power`     — Fig. 5 reproduction for one pattern/destination.
//! * `codegen`   — emit the converted code (OpenACC/OpenMP/OpenCL).
//! * `calibrate` — execute the AOT HLO artifacts on PJRT (real timing).
//! * `report`    — print the simulated testbed (Fig. 4).
//! * `obs`       — render a `--metrics-json` telemetry dump as tables.

use enadapt::canalyze;
use enadapt::coordinator::{self, BaselineSource, Destination, JobConfig};
use enadapt::runtime;
use enadapt::search::{FitnessSpec, SearchStrategy};
use enadapt::util::args::{flag, opt, App, ArgError, CmdSpec, Parsed};
use enadapt::util::json::Json;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn app() -> App {
    let common = || {
        vec![
            opt("seed", "42", "search / measurement-noise seed"),
            opt(
                "baseline",
                "paper",
                "CPU baseline: 'paper' (14 s), 'measured' (run HLO), or seconds",
            ),
            opt(
                "meter",
                "ipmi",
                "power meter backend: ipmi (1 Hz whole-server), rapl \
                 (high-rate per-component), oracle (exact)",
            ),
            opt(
                "watt-cap",
                "",
                "operator Watt cap: reject patterns whose measured peak \
                 exceeds this draw (empty = none)",
            ),
            opt(
                "strategy",
                "ga",
                "pattern-search strategy: ga (§3.1 evolutionary; FPGA uses \
                 the §3.2 narrowing funnel), exhaustive (whole space, small \
                 widths), anneal (deterministic hill-climber)",
            ),
            flag(
                "blocks",
                "function-block offloading: detect algorithmic blocks \
                 (matmul/fft/histogram) and let the search substitute \
                 device library / IP-core implementations",
            ),
            flag(
                "mixed-dest",
                "per-loop destination genes: one plan may place different \
                 loops on different devices (gpu/fpga/many-core), with \
                 cross-device transfer edges charged in the verifier",
            ),
            opt(
                "trace-out",
                "",
                "write a Chrome trace-event JSON file (spans + W·s counter \
                 track) loadable in Perfetto / chrome://tracing (empty = off)",
            ),
            opt(
                "metrics-json",
                "",
                "write the obs metrics registry (counters/gauges/histograms) \
                 as JSON; render it with `enadapt obs <file>` (empty = off)",
            ),
            flag("json", "emit machine-readable JSON on stdout"),
        ]
    };
    App {
        name: "enadapt",
        about: "power-aware automatic offloading (Yamato 2021 reproduction)",
        commands: vec![
            CmdSpec {
                name: "analyze",
                about: "analyze a source: loop table, parallelizability, profile",
                opts: vec![
                    flag("json", "emit JSON"),
                    flag("profile-ops", "dump the interpreter opcode/pair histogram"),
                ],
                positionals: vec!["source"],
            },
            CmdSpec {
                name: "blocks",
                about: "list detectable function blocks (matmul/fft/histogram) \
                        and their device library / IP-core implementations",
                opts: vec![flag("json", "emit JSON")],
                positionals: vec!["source"],
            },
            CmdSpec {
                name: "offload",
                about: "run the full Steps 1-7 offload job",
                opts: {
                    let mut o = common();
                    o.push(opt("dest", "fpga", "destination: fpga|gpu|manycore|mixed"));
                    o.push(flag(
                        "pareto",
                        "print the non-dominated (time x energy x peak-W) front",
                    ));
                    o.push(flag("time-only", "ablation: previous papers' time-only fitness"));
                    o.push(flag("no-transfer-opt", "ablation: disable §3.1 transfer batching"));
                    o.push(opt("generations", "20", "GA generations (gpu/manycore)"));
                    o.push(opt("population", "16", "GA population (gpu/manycore)"));
                    o
                },
                positionals: vec!["source"],
            },
            CmdSpec {
                name: "fleet",
                about: "run the full workload x destination matrix concurrently \
                        (shared cross-job measurement cache)",
                opts: {
                    let mut o = common();
                    o.push(opt("workers", "0", "concurrent jobs (0 = one per core)"));
                    o.push(opt(
                        "cache",
                        "",
                        "JSON cache file for cross-invocation trial reuse (empty = none)",
                    ));
                    o.push(opt(
                        "cache-log",
                        "",
                        "append-only measurement log: replayed on start, then every \
                         completed trial is appended + flushed (empty = none)",
                    ));
                    o.push(opt("generations", "20", "GA generations (gpu/manycore stages)"));
                    o.push(opt("population", "16", "GA population (gpu/manycore stages)"));
                    o
                },
                positionals: vec![],
            },
            CmdSpec {
                name: "sched",
                about: "trace-driven power-budget fleet scheduler on a simulated \
                        cluster (fleet Watt cap, drift-triggered re-adaptation)",
                opts: {
                    let mut o = common();
                    o.push(opt(
                        "trace",
                        "",
                        "arrival-trace file: '<t> <workload> <dest> [scale]' lines plus \
                         '<t> cap <W|none>' operator events (empty = synthetic Poisson)",
                    ));
                    o.push(opt("arrivals", "32", "synthetic arrivals when no --trace"));
                    o.push(opt("rate", "0.1", "synthetic Poisson arrival rate, jobs/s"));
                    o.push(opt(
                        "fleet-watt-cap",
                        "",
                        "fleet-wide cap on the committed mean draw, Watts (empty = none)",
                    ));
                    o.push(opt("nodes", "2", "r740-pac nodes in the simulated cluster"));
                    o.push(opt(
                        "gate-after",
                        "30",
                        "power-gate idle accelerators after this many idle seconds (0 = never)",
                    ));
                    o.push(opt(
                        "drift-tolerance",
                        "0.25",
                        "relative production drift before a deployment is re-searched",
                    ));
                    o.push(opt(
                        "drift-after",
                        "",
                        "synthetic traces: arrivals from this index on run at --drift-scale",
                    ));
                    o.push(opt("drift-scale", "2.0", "workload scale applied after --drift-after"));
                    o.push(opt(
                        "cache",
                        "",
                        "JSON cache file for cross-invocation trial reuse (empty = none)",
                    ));
                    o.push(opt(
                        "cache-log",
                        "",
                        "append-only measurement log: replayed on start, then every \
                         completed trial is appended + flushed (empty = none)",
                    ));
                    o.push(opt("generations", "20", "GA generations (gpu/manycore stages)"));
                    o.push(opt("population", "16", "GA population (gpu/manycore stages)"));
                    o.push(opt(
                        "clusters",
                        "1",
                        "federate: shard arrivals across this many clusters (each gets \
                         its own --nodes cluster; Watt caps are rebalanced by demand)",
                    ));
                    o.push(opt(
                        "shard-seed",
                        "0",
                        "seed for the arrival-to-cluster shard assignment",
                    ));
                    o.push(flag(
                        "parallel-clusters",
                        "run federation probe + cluster simulations concurrently \
                         (byte-identical report to the serial path)",
                    ));
                    o.push(flag(
                        "rebalance-at-caps",
                        "federation: re-probe demand and re-split the Watt budget at \
                         every trace cap event instead of one up-front probe",
                    ));
                    o.push(flag(
                        "legacy-loop",
                        "run the retained time-stepped reference loop instead of the \
                         event-driven engine (same ledger, bit for bit)",
                    ));
                    o.push(opt(
                        "series-out",
                        "",
                        "write the deterministic per-node committed/dynamic/idle-W \
                         virtual-time series as JSON (empty = off)",
                    ));
                    o
                },
                positionals: vec![],
            },
            CmdSpec {
                name: "cache",
                about: "measurement-cache maintenance (actions: compact — fold an \
                        append-only --log into its --snapshot; stats — per-shard \
                        occupancy of a --snapshot)",
                opts: vec![
                    opt(
                        "log",
                        "",
                        "append-only measurement log written by --cache-log runs",
                    ),
                    opt(
                        "snapshot",
                        "",
                        "stable v3 JSON snapshot to fold the log into (created if absent)",
                    ),
                    flag("json", "emit machine-readable JSON on stdout"),
                ],
                positionals: vec!["action"],
            },
            CmdSpec {
                name: "power",
                about: "Fig. 5: power trace of cpu-only vs offloaded best pattern",
                opts: {
                    let mut o = common();
                    o.push(opt("dest", "fpga", "destination: fpga|gpu|manycore"));
                    o
                },
                positionals: vec!["source"],
            },
            CmdSpec {
                name: "codegen",
                about: "emit converted code for the chosen pattern",
                opts: vec![
                    opt("dest", "fpga", "destination: fpga|gpu|manycore"),
                    opt("seed", "42", "search seed"),
                ],
                positionals: vec!["source"],
            },
            CmdSpec {
                name: "calibrate",
                about: "execute the AOT HLO artifacts via PJRT and report timings",
                opts: vec![opt("runs", "3", "timed executions per artifact")],
                positionals: vec![],
            },
            CmdSpec {
                name: "report",
                about: "print the simulated verification environment (Fig. 4)",
                opts: vec![],
                positionals: vec![],
            },
            CmdSpec {
                name: "obs",
                about: "render a --metrics-json telemetry dump as summary tables",
                opts: vec![flag("json", "re-emit the dump as compact JSON")],
                positionals: vec!["metrics"],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match app().parse(&argv) {
        Ok(p) => p,
        Err(ArgError::Help(h)) => {
            println!("{h}");
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&parsed) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Load a bundled workload by (tolerant) name or a file from disk. When
/// neither resolves, the error lists the valid bundled names.
fn load_source(arg: &str) -> enadapt::Result<(String, String)> {
    if let Some((name, src)) = workloads::resolve(arg) {
        return Ok((format!("{name}.c"), src.to_string()));
    }
    match std::fs::read_to_string(arg) {
        Ok(text) => Ok((arg.to_string(), text)),
        Err(e) => Err(enadapt::Error::Config(format!(
            "unknown workload '{arg}' and not a readable file ({e}); \
             bundled workloads: {}",
            workloads::names().join(", ")
        ))),
    }
}

fn parse_dest(s: &str) -> enadapt::Result<Destination> {
    Destination::parse(s)
}

fn parse_baseline(s: &str) -> enadapt::Result<BaselineSource> {
    Ok(match s {
        "paper" => BaselineSource::Fixed(14.0),
        "measured" => BaselineSource::MeasuredHlo {
            artifact: "mriq_cpu_small".into(),
            full_k: 2048,
            full_x: 262_144,
        },
        other => BaselineSource::Fixed(other.parse::<f64>().map_err(|_| {
            enadapt::Error::Config(format!("bad --baseline '{other}' (paper|measured|<secs>)"))
        })?),
    })
}

fn job_config(p: &Parsed) -> enadapt::Result<JobConfig> {
    let mut cfg = JobConfig {
        seed: p
            .get_u64("seed")
            .map_err(|e| enadapt::Error::Config(e.to_string()))?,
        destination: parse_dest(p.get("dest").unwrap_or("fpga"))?,
        baseline: parse_baseline(p.get("baseline").unwrap_or("paper"))?,
        ..Default::default()
    };
    if let Some(name) = p.get("meter").filter(|s| !s.is_empty()) {
        cfg.env.meter = enadapt::power::MeterConfig::from_name(name).ok_or_else(|| {
            enadapt::Error::Config(format!("unknown meter '{name}' (ipmi|rapl|oracle)"))
        })?;
    }
    if let Some(name) = p.get("strategy").filter(|s| !s.is_empty()) {
        cfg.ga_flow.strategy = SearchStrategy::from_name(name).ok_or_else(|| {
            enadapt::Error::Config(format!(
                "unknown strategy '{name}' (ga|exhaustive|anneal)"
            ))
        })?;
    }
    if p.flag("time-only") {
        cfg.map_fitness(|_| FitnessSpec::time_only());
    }
    if let Some(cap) = p.get("watt-cap").filter(|s| !s.is_empty()) {
        let cap: f64 = cap.parse().map_err(|_| {
            enadapt::Error::Config(format!("bad --watt-cap '{cap}' (expected Watts)"))
        })?;
        cfg.map_fitness(|f| f.with_watt_cap(cap));
    }
    if p.flag("no-transfer-opt") {
        cfg.ga_flow.transfer_opt = false;
        cfg.fpga_flow.transfer_opt = false;
    }
    if p.flag("blocks") {
        cfg.blocks = true;
    }
    if p.flag("mixed-dest") {
        cfg.mixed_dest = Some(enadapt::offload::MixedDestSpec::default());
    }
    if let Ok(g) = p.get_usize("generations") {
        cfg.ga_flow.ga.generations = g;
    }
    if let Ok(n) = p.get_usize("population") {
        cfg.ga_flow.ga.population = n;
    }
    cfg.ga_flow.seed = cfg.seed;
    Ok(cfg)
}

/// Telemetry output paths parsed from the common CLI flags. The matching
/// obs pillars are enabled before the command runs (telemetry stays
/// entirely off otherwise); the files are written once it succeeds.
struct ObsOutputs {
    trace_out: Option<std::path::PathBuf>,
    metrics_json: Option<std::path::PathBuf>,
    series_out: Option<std::path::PathBuf>,
}

impl ObsOutputs {
    fn configure(p: &Parsed) -> Self {
        let path = |name: &str| {
            p.get(name)
                .filter(|s| !s.is_empty())
                .map(std::path::PathBuf::from)
        };
        let out = Self {
            trace_out: path("trace-out"),
            metrics_json: path("metrics-json"),
            series_out: path("series-out"),
        };
        if out.trace_out.is_some() {
            // The trace carries the W·s counter track alongside spans.
            enadapt::obs::enable(enadapt::obs::SPANS | enadapt::obs::SERIES);
        }
        if out.metrics_json.is_some() {
            enadapt::obs::enable(enadapt::obs::METRICS);
        }
        if out.series_out.is_some() {
            enadapt::obs::enable(enadapt::obs::SERIES);
        }
        out
    }

    fn write(&self) -> enadapt::Result<()> {
        if let Some(path) = &self.trace_out {
            enadapt::obs::chrome::write(path)?;
            eprintln!(
                "trace written to {} (load in Perfetto / chrome://tracing)",
                path.display()
            );
        }
        if let Some(path) = &self.metrics_json {
            std::fs::write(
                path,
                enadapt::obs::metrics::snapshot().to_string_pretty() + "\n",
            )?;
            eprintln!(
                "metrics written to {} (render with `enadapt obs {}`)",
                path.display(),
                path.display()
            );
        }
        if let Some(path) = &self.series_out {
            std::fs::write(path, enadapt::obs::series::to_json().to_string_compact() + "\n")?;
            eprintln!("W·s series written to {}", path.display());
        }
        Ok(())
    }
}

fn dispatch(p: &Parsed) -> enadapt::Result<()> {
    let outputs = ObsOutputs::configure(p);
    run_command(p)?;
    outputs.write()
}

fn run_command(p: &Parsed) -> enadapt::Result<()> {
    match p.cmd.as_str() {
        "analyze" => {
            let (name, src) = load_source(p.pos(0).unwrap())?;
            let limits = canalyze::ProfileLimits {
                count_ops: p.flag("profile-ops"),
                ..Default::default()
            };
            let an = canalyze::analyze_source_with_limits(&name, &src, limits)?;
            if p.flag("json") {
                let loops: Vec<Json> = an
                    .loops
                    .iter()
                    .map(|l| {
                        Json::obj(vec![
                            ("id", Json::num(l.id.0 as f64)),
                            ("func", Json::str(l.func.clone())),
                            ("line", Json::num(l.line as f64)),
                            ("parallelizable", Json::Bool(l.parallelizable)),
                            (
                                "reason",
                                l.not_parallel_reason
                                    .clone()
                                    .map(Json::str)
                                    .unwrap_or(Json::Null),
                            ),
                        ])
                    })
                    .collect();
                println!(
                    "{}",
                    Json::obj(vec![
                        ("file", Json::str(an.file.clone())),
                        ("n_loops", Json::num(an.n_loops() as f64)),
                        ("processable", Json::num(an.parallelizable_ids().len() as f64)),
                        ("loops", Json::arr(loops)),
                    ])
                    .to_string_pretty()
                );
            } else {
                println!("{}", coordinator::report::loop_table(&an));
                println!(
                    "{} of {} loop statements are processable (offloadable)",
                    an.parallelizable_ids().len(),
                    an.n_loops()
                );
            }
            if let Some(ops) = &an.op_profile {
                println!("\n{}", ops.render());
            } else if p.flag("profile-ops") {
                println!("\n(no main() — nothing executed, no op histogram)");
            }
            Ok(())
        }
        "blocks" => {
            let (name, src) = load_source(p.pos(0).unwrap())?;
            let an = canalyze::analyze_source(&name, &src)?;
            let db = enadapt::funcblock::BlockDb::standard();
            let found = enadapt::funcblock::detect(&an, &db);
            let impls_of = |kind| {
                use enadapt::devices::DeviceKind;
                [DeviceKind::Gpu, DeviceKind::Fpga, DeviceKind::ManyCore]
                    .into_iter()
                    .filter_map(|d| {
                        db.entry(kind)
                            .and_then(|e| e.impl_for(d))
                            .map(|i| (d, i.library))
                    })
                    .collect::<Vec<_>>()
            };
            if p.flag("json") {
                let blocks: Vec<Json> = found
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("kind", Json::str(b.kind.name())),
                            ("func", Json::str(b.func.clone())),
                            ("line", Json::num(b.line as f64)),
                            ("root", Json::num(b.root.0 as f64)),
                            (
                                "covered",
                                Json::arr(
                                    b.covered.iter().map(|id| Json::num(id.0 as f64)).collect(),
                                ),
                            ),
                            ("via", Json::str(b.via.name())),
                            (
                                "impls",
                                Json::obj(
                                    impls_of(b.kind)
                                        .into_iter()
                                        .map(|(d, lib)| (d.name(), Json::str(lib)))
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect();
                println!(
                    "{}",
                    Json::obj(vec![
                        ("file", Json::str(an.file.clone())),
                        ("n_blocks", Json::num(found.len() as f64)),
                        ("blocks", Json::arr(blocks)),
                    ])
                    .to_string_pretty()
                );
            } else {
                let mut t = enadapt::util::tablefmt::Table::new(&[
                    "block", "kind", "func", "line", "root", "covered", "via", "implementations",
                ]);
                for (i, b) in found.iter().enumerate() {
                    let covered: Vec<String> =
                        b.covered.iter().map(|id| id.to_string()).collect();
                    let impls: Vec<String> = impls_of(b.kind)
                        .into_iter()
                        .map(|(d, lib)| format!("{}: {}", d.name(), lib))
                        .collect();
                    t.row(&[
                        format!("B{i}"),
                        b.kind.name().to_string(),
                        b.func.clone(),
                        b.line.to_string(),
                        b.root.to_string(),
                        covered.join(","),
                        b.via.name().to_string(),
                        impls.join("; "),
                    ]);
                }
                println!("{}", t.render());
                println!(
                    "{} function block(s) detected (run `enadapt offload {} --blocks` \
                     to search block-substituted plans)",
                    found.len(),
                    an.file.trim_end_matches(".c"),
                );
            }
            Ok(())
        }
        "offload" => {
            let (name, src) = load_source(p.pos(0).unwrap())?;
            let cfg = job_config(p)?;
            let report = coordinator::run_job(&name, &src, &cfg)?;
            if p.flag("json") {
                // The front is part of the JSON report already.
                println!(
                    "{}",
                    coordinator::report::job_json(&report).to_string_pretty()
                );
            } else {
                println!("{}", coordinator::report::render_job(&report));
                if p.flag("pareto") {
                    // Mark the front's own knee under the configured
                    // scalarization — guaranteed to be a front row (the
                    // flow's winner can, in sensor-noise edge cases, sit a
                    // float-ulp off the front).
                    let knee = report.front.knee(&cfg.fitness).map(|s| s.genome.clone());
                    match &report.mixed_spec {
                        // Mixed fronts carry widened destination-code
                        // genomes — decode rows to letter plans.
                        Some(spec) => println!(
                            "{}",
                            coordinator::report::pareto_table_with(
                                &report.front,
                                knee.as_ref(),
                                |g| enadapt::offload::plan_of_genome(&report.app, spec, g)
                                    .to_string(),
                            )
                        ),
                        None => println!(
                            "{}",
                            coordinator::report::pareto_table(&report.front, knee.as_ref())
                        ),
                    }
                }
            }
            Ok(())
        }
        "fleet" => {
            let mut template = job_config(p)?;
            // Jobs are the unit of concurrency; per-generation trial
            // threads on top would oversubscribe the machine.
            template.ga_flow.parallel_trials = false;
            let cfg = coordinator::FleetConfig {
                template,
                workers: p
                    .get_usize("workers")
                    .map_err(|e| enadapt::Error::Config(e.to_string()))?,
                cache_path: p
                    .get("cache")
                    .filter(|s| !s.is_empty())
                    .map(std::path::PathBuf::from),
                cache_log: p
                    .get("cache-log")
                    .filter(|s| !s.is_empty())
                    .map(std::path::PathBuf::from),
                share_cache: true,
            };
            let specs = coordinator::fleet::full_matrix();
            let report = coordinator::run_fleet(&specs, &cfg)?;
            if p.flag("json") {
                println!("{}", report.to_json().to_string_pretty());
            } else {
                println!("{}", report.table());
            }
            Ok(())
        }
        "sched" => {
            let mut template = job_config(p)?;
            template.ga_flow.parallel_trials = false;
            let fleet_watt_cap = match p.get("fleet-watt-cap").filter(|s| !s.is_empty()) {
                Some(w) => {
                    let cap = w.parse::<f64>().ok().filter(|c| c.is_finite() && *c > 0.0);
                    Some(cap.ok_or_else(|| {
                        enadapt::Error::Config(format!(
                            "bad --fleet-watt-cap '{w}' (expected positive Watts)"
                        ))
                    })?)
                }
                None => None,
            };
            let gate_after = p
                .get_f64("gate-after")
                .map_err(|e| enadapt::Error::Config(e.to_string()))?;
            let n_nodes = p
                .get_usize("nodes")
                .map_err(|e| enadapt::Error::Config(e.to_string()))?;
            let seed = template.seed;
            let cfg = enadapt::coordinator::SchedConfig {
                template,
                nodes: (0..n_nodes.max(1))
                    .map(|i| enadapt::devices::NodeSpec::r740_pac(&format!("node{i}")))
                    .collect(),
                fleet_watt_cap,
                idle_policy: if gate_after > 0.0 {
                    enadapt::power::IdlePolicy::gate_after(gate_after)
                } else {
                    enadapt::power::IdlePolicy::default()
                },
                drift_tolerance: p
                    .get_f64("drift-tolerance")
                    .map_err(|e| enadapt::Error::Config(e.to_string()))?,
                cache_path: p
                    .get("cache")
                    .filter(|s| !s.is_empty())
                    .map(std::path::PathBuf::from),
                cache_log: p
                    .get("cache-log")
                    .filter(|s| !s.is_empty())
                    .map(std::path::PathBuf::from),
                legacy_loop: p.flag("legacy-loop"),
            };
            let trace = match p.get("trace").filter(|s| !s.is_empty()) {
                Some(path) => {
                    enadapt::coordinator::ArrivalTrace::load(std::path::Path::new(path))?
                }
                None => {
                    let rate = p
                        .get_f64("rate")
                        .map_err(|e| enadapt::Error::Config(e.to_string()))?;
                    if !rate.is_finite() || rate <= 0.0 {
                        return Err(enadapt::Error::Config(format!(
                            "bad --rate '{rate}' (expected positive jobs/s)"
                        )));
                    }
                    let mut syn = enadapt::coordinator::SyntheticTraceConfig::standard(
                        p.get_usize("arrivals")
                            .map_err(|e| enadapt::Error::Config(e.to_string()))?,
                        rate,
                        seed,
                    );
                    if let Some(k) = p.get("drift-after").filter(|s| !s.is_empty()) {
                        syn.drift_after = Some(k.parse::<usize>().map_err(|_| {
                            enadapt::Error::Config(format!("bad --drift-after '{k}'"))
                        })?);
                        let scale = p
                            .get_f64("drift-scale")
                            .map_err(|e| enadapt::Error::Config(e.to_string()))?;
                        if !scale.is_finite() || scale <= 0.0 {
                            return Err(enadapt::Error::Config(format!(
                                "bad --drift-scale '{scale}' (expected positive)"
                            )));
                        }
                        syn.drift_scale = scale;
                    }
                    enadapt::coordinator::ArrivalTrace::poisson(&syn)
                }
            };
            let clusters = p
                .get_usize("clusters")
                .map_err(|e| enadapt::Error::Config(e.to_string()))?;
            if clusters > 1 {
                let fcfg = enadapt::coordinator::FederationConfig {
                    base: cfg,
                    clusters,
                    shard_seed: p
                        .get_u64("shard-seed")
                        .map_err(|e| enadapt::Error::Config(e.to_string()))?,
                    parallel: p.flag("parallel-clusters"),
                    rebalance_at_caps: p.flag("rebalance-at-caps"),
                };
                let report = enadapt::coordinator::run_federated(&trace, &fcfg)?;
                if p.flag("json") {
                    println!("{}", report.to_json().to_string_pretty());
                } else {
                    println!("{}", report.table());
                }
                return Ok(());
            }
            let report = enadapt::coordinator::run_sched(&trace, &cfg)?;
            if p.flag("json") {
                println!("{}", report.to_json().to_string_pretty());
            } else {
                println!("{}", report.table());
            }
            Ok(())
        }
        "cache" => {
            let action = p.pos(0).unwrap();
            match action {
                "compact" => {
                    let log = p.get("log").filter(|s| !s.is_empty()).ok_or_else(|| {
                        enadapt::Error::Config("cache compact: --log is required".into())
                    })?;
                    let snapshot =
                        p.get("snapshot").filter(|s| !s.is_empty()).ok_or_else(|| {
                            enadapt::Error::Config("cache compact: --snapshot is required".into())
                        })?;
                    let stats = enadapt::util::measure_cache::MeasureCache::compact(
                        std::path::Path::new(log),
                        std::path::Path::new(snapshot),
                    )?;
                    if p.flag("json") {
                        println!(
                            "{}",
                            Json::obj(vec![
                                ("snapshot_entries", Json::num(stats.snapshot_entries as f64)),
                                ("log_records", Json::num(stats.log_records as f64)),
                                ("entries", Json::num(stats.entries as f64)),
                            ])
                            .to_string_pretty()
                        );
                    } else {
                        println!(
                            "compacted {log} into {snapshot}: {} snapshot + {} log record(s) \
                             -> {} entries (log truncated)",
                            stats.snapshot_entries, stats.log_records, stats.entries
                        );
                    }
                    Ok(())
                }
                "stats" => {
                    let snapshot =
                        p.get("snapshot").filter(|s| !s.is_empty()).ok_or_else(|| {
                            enadapt::Error::Config("cache stats: --snapshot is required".into())
                        })?;
                    let cache = enadapt::util::measure_cache::MeasureCache::load(
                        std::path::Path::new(snapshot),
                    )?;
                    let stats = cache.shard_stats();
                    if p.flag("json") {
                        let shards: Vec<Json> = stats
                            .iter()
                            .map(|s| {
                                Json::obj(vec![
                                    ("shard", Json::num(s.shard as f64)),
                                    ("entries", Json::num(s.entries as f64)),
                                ])
                            })
                            .collect();
                        println!(
                            "{}",
                            Json::obj(vec![
                                ("entries", Json::num(cache.len() as f64)),
                                ("shards", Json::arr(shards)),
                            ])
                            .to_string_pretty()
                        );
                    } else {
                        let mut t =
                            enadapt::util::tablefmt::Table::new(&["shard", "entries", "share"]);
                        let total = cache.len().max(1);
                        for s in &stats {
                            t.row(&[
                                format!("{:02}", s.shard),
                                s.entries.to_string(),
                                format!("{:.0}%", 100.0 * s.entries as f64 / total as f64),
                            ]);
                        }
                        println!("{}", t.render());
                        println!(
                            "{} entries across {} shards in {snapshot}",
                            cache.len(),
                            stats.len()
                        );
                    }
                    Ok(())
                }
                other => Err(enadapt::Error::Config(format!(
                    "unknown cache action '{other}' (supported: compact, stats)"
                ))),
            }
        }
        "power" => {
            let (name, src) = load_source(p.pos(0).unwrap())?;
            let cfg = job_config(p)?;
            let report = coordinator::run_job(&name, &src, &cfg)?;
            println!(
                "{}",
                coordinator::report::fig5(&report.baseline, &report.production)
            );
            Ok(())
        }
        "codegen" => {
            let (name, src) = load_source(p.pos(0).unwrap())?;
            let cfg = job_config(p)?;
            let report = coordinator::run_job(&name, &src, &cfg)?;
            match &report.generated {
                coordinator::GeneratedCode::OpenAcc(c) | coordinator::GeneratedCode::OpenMp(c) => {
                    println!("{c}")
                }
                coordinator::GeneratedCode::OpenCl(b) => {
                    println!("/* ===== kernels (.cl) ===== */\n{}", b.kernel_source);
                    println!("/* ===== host (.c) ===== */\n{}", b.host_source);
                }
                coordinator::GeneratedCode::Mixed(c) => println!("{c}"),
                coordinator::GeneratedCode::Unchanged => println!("{src}"),
            }
            Ok(())
        }
        "calibrate" => {
            let runs = p.get_u64("runs").unwrap_or(3) as u32;
            let arts = runtime::load_artifacts(&runtime::default_dir())?;
            let rt = runtime::HloRuntime::cpu()?;
            println!("platform: {} ({} devices)", rt.platform(), rt.device_count());
            for v in &arts.variants {
                let model = rt.load_artifact(v)?;
                let t = runtime::time_model(&model, 1, runs)?;
                let full = runtime::scale_to_full(t.mean_s, v.num_k, v.num_x, 2048, 262_144);
                println!(
                    "{:<22} K={:<4} X={:<5} mean {:>9.3} ms (±{:.3})  → full-size est {:>7.2} s",
                    v.name,
                    v.num_k,
                    v.num_x,
                    t.mean_s * 1e3,
                    t.std_s * 1e3,
                    full
                );
            }
            Ok(())
        }
        "report" => {
            println!(
                "{}",
                coordinator::report::env_report(&VerifEnvConfig::r740_pac())
            );
            let an = canalyze::analyze_source("mriq.c", workloads::MRIQ_C)?;
            let cfg = VerifEnvConfig::r740_pac();
            let app = AppModel::from_analysis(&an, &cfg.cpu, 14.0)?;
            println!(
                "\nMRI-Q app model: {} candidates, {:.1} s CPU baseline, work scale {:.0}x",
                app.genome_len(),
                app.total_cpu_s,
                app.work_scale
            );
            Ok(())
        }
        "obs" => {
            let path = p.pos(0).unwrap();
            let text = std::fs::read_to_string(path)?;
            let doc = enadapt::util::json::parse(&text).map_err(|e| {
                enadapt::Error::Config(format!("bad metrics JSON in {path}: {e}"))
            })?;
            if p.flag("json") {
                println!("{}", doc.to_string_compact());
                return Ok(());
            }
            let section = |key: &str| -> Vec<(String, Json)> {
                match doc.get(key) {
                    Some(Json::Obj(m)) => m.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
                    _ => Vec::new(),
                }
            };
            let counters = section("counters");
            if !counters.is_empty() {
                let mut t = enadapt::util::tablefmt::Table::new(&["counter", "value"]);
                for (k, v) in &counters {
                    t.row(&[k.clone(), format!("{:.0}", v.as_f64().unwrap_or(0.0))]);
                }
                println!("{}", t.render());
            }
            let gauges = section("gauges");
            if !gauges.is_empty() {
                let mut t = enadapt::util::tablefmt::Table::new(&["gauge", "value"]);
                for (k, v) in &gauges {
                    t.row(&[k.clone(), format!("{:.3}", v.as_f64().unwrap_or(0.0))]);
                }
                println!("{}", t.render());
            }
            let hists = section("histograms");
            if !hists.is_empty() {
                let mut t = enadapt::util::tablefmt::Table::new(&[
                    "histogram",
                    "count",
                    "log2 buckets (bucket:count)",
                ]);
                for (k, v) in &hists {
                    let count = v.get("count").and_then(|c| c.as_f64()).unwrap_or(0.0);
                    let buckets = v
                        .get("buckets")
                        .and_then(|b| b.as_arr())
                        .map(|arr| {
                            arr.iter()
                                .filter_map(|pair| {
                                    let kv = pair.as_arr()?;
                                    Some(format!(
                                        "{}:{}",
                                        kv.first()?.as_f64()? as u64,
                                        kv.get(1)?.as_f64()? as u64
                                    ))
                                })
                                .collect::<Vec<_>>()
                                .join(" ")
                        })
                        .unwrap_or_default();
                    t.row(&[k.clone(), format!("{count:.0}"), buckets]);
                }
                println!("{}", t.render());
            }
            if counters.is_empty() && gauges.is_empty() && hists.is_empty() {
                println!("(no metrics recorded in {path})");
            }
            Ok(())
        }
        other => Err(enadapt::Error::Config(format!("unhandled command {other}"))),
    }
}
