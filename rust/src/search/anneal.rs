//! Deterministic simulated-annealing hill-climber — the cheap ablation
//! arm of the strategy suite.
//!
//! Single-bit neighborhood, geometric cooling, Metropolis acceptance on
//! the *relative* loss (the paper's evaluation values live around
//! `1/sqrt(W·s)`, so absolute temperatures would be meaningless), with a
//! restart chain starting from the all-CPU baseline. All randomness comes
//! from the search seed; the measure-once [`super::Archive`] makes
//! revisits free, so an annealing run costs at most `steps + restarts`
//! verification trials and usually far fewer distinct ones.

use super::genome::Genome;
use super::strategy::{SearchCtx, Strategy};
use crate::util::prng::Pcg32;
use crate::Result;

/// Annealer hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnnealConfig {
    /// Total proposal evaluations across all restarts (default 320 ≈ the
    /// GA default's 16 × 20 budget, for like-for-like ablations).
    pub steps: usize,
    /// Initial temperature, relative to the current value.
    pub t0: f64,
    /// Geometric cooling factor applied per step.
    pub cooling: f64,
    /// Independent chains: restart 0 starts at the all-CPU pattern, later
    /// restarts at random sparse patterns.
    pub restarts: usize,
}

impl Default for AnnealConfig {
    fn default() -> Self {
        Self {
            steps: 320,
            t0: 0.2,
            cooling: 0.985,
            restarts: 2,
        }
    }
}

/// The annealing [`Strategy`].
#[derive(Debug, Clone, Copy)]
pub struct Annealing {
    /// Hyper-parameters.
    pub cfg: AnnealConfig,
}

impl Strategy for Annealing {
    fn name(&self) -> &'static str {
        "anneal"
    }

    fn search(&self, ctx: &mut SearchCtx<'_>) -> Result<()> {
        let cfg = &self.cfg;
        let len = ctx.genome_len();
        let restarts = cfg.restarts.max(1);
        let steps = (cfg.steps / restarts).max(1);
        let mut rng = Pcg32::seed_from_u64(ctx.seed());
        let mut best = f64::NEG_INFINITY;

        for restart in 0..restarts {
            let mut cur = if restart == 0 {
                Genome::zeros(len)
            } else {
                Genome::random(len, 0.25, &mut rng)
            };
            let mut cur_v = ctx.values(std::slice::from_ref(&cur))[0];
            if cur_v > best {
                best = cur_v;
            }
            let mut t = cfg.t0;
            for _ in 0..steps {
                let mut cand = cur.clone();
                let bit = rng.below_usize(len);
                cand.bits[bit] = !cand.bits[bit];
                let cand_v = ctx.values(std::slice::from_ref(&cand))[0];
                if cand_v > best {
                    best = cand_v;
                }
                // Metropolis on the relative loss. NaN-safe: a NaN
                // candidate fails both branches (rejected), and a NaN
                // *state* accepts any move so the chain cannot get stuck.
                let accept = if cand_v > cur_v || cur_v.is_nan() {
                    true
                } else {
                    let rel = (cand_v - cur_v) / cur_v.abs().max(1e-12);
                    rng.chance((rel / t.max(1e-12)).exp())
                };
                if accept {
                    cur = cand;
                    cur_v = cand_v;
                }
                ctx.record(best, cur_v);
                t *= cfg.cooling;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::strategy::run_synthetic;

    #[test]
    fn climbs_a_unimodal_landscape_to_the_top() {
        // OneMax is monotone in Hamming distance: with a near-zero
        // temperature the chain is a pure hill climb, so 400 single-bit
        // proposals from zeros reach all-ones on an 8-bit space.
        let cfg = AnnealConfig {
            steps: 400,
            t0: 0.001,
            cooling: 0.99,
            restarts: 1,
        };
        let r = run_synthetic(&Annealing { cfg }, 8, 5, |g| g.ones() as f64).unwrap();
        assert_eq!(r.best.ones(), 8, "best {}", r.best);
        assert!(r.measured <= 256, "measure-once bounds distinct trials");
    }

    #[test]
    fn history_best_is_monotone_and_budget_is_respected() {
        let cfg = AnnealConfig {
            steps: 60,
            restarts: 3,
            ..Default::default()
        };
        let r = run_synthetic(&Annealing { cfg }, 10, 9, |g| g.ones() as f64).unwrap();
        for w in r.history.windows(2) {
            assert!(w[1].best >= w[0].best);
        }
        // Distinct measurements never exceed proposals + restart starts.
        assert!(r.measured <= 60 + 3, "measured {}", r.measured);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let strat = Annealing {
            cfg: AnnealConfig::default(),
        };
        let a = run_synthetic(&strat, 12, 7, |g| g.ones() as f64).unwrap();
        let b = run_synthetic(&strat, 12, 7, |g| g.ones() as f64).unwrap();
        assert_eq!(a.best, b.best);
        assert_eq!(a.measured, b.measured);
        assert_eq!(a.best_value, b.best_value);
    }

    #[test]
    fn starts_at_the_all_cpu_baseline() {
        let mut first: Option<Genome> = None;
        run_synthetic(
            &Annealing {
                cfg: AnnealConfig {
                    steps: 10,
                    restarts: 1,
                    ..Default::default()
                },
            },
            5,
            3,
            |g| {
                if first.is_none() {
                    first = Some(g.clone());
                }
                g.ones() as f64
            },
        )
        .unwrap();
        assert_eq!(first.unwrap().ones(), 0);
    }
}
