//! Search genome: one bit per parallelizable loop statement — 1 = offload
//! to the device, 0 = keep on the CPU (§3.1: "it sets 1 for GPU execution
//! and 0 for CPU execution; the value is set and geneticized"). Shared by
//! every [`super::Strategy`], not just the GA.
//!
//! When function-block offloading is enabled
//! ([`crate::funcblock`]), the genome gains one **block destination
//! gene** per detected block, appended after the loop genes: 1 =
//! substitute the block with the destination device's library / IP-core
//! implementation. Strategies treat the combined vector uniformly; the
//! verifier masks loop genes covered by an active block
//! ([`crate::verifier::AppModel::regions`]). [`Genome::plan_split`] and
//! [`Genome::block_ones`] are the layout accessors.

use crate::util::prng::Pcg32;

/// A candidate offload pattern as a bit string.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Genome {
    /// Gene per candidate loop (index = position in the candidate list).
    pub bits: Vec<bool>,
}

impl Genome {
    /// All-CPU pattern.
    pub fn zeros(len: usize) -> Self {
        Self {
            bits: vec![false; len],
        }
    }

    /// Single-loop pattern.
    pub fn single(len: usize, idx: usize) -> Self {
        let mut g = Self::zeros(len);
        g.bits[idx] = true;
        g
    }

    /// Pattern number `idx` of the `2^len` space: bit `i` of `idx` maps to
    /// gene `i`, so index 0 is the all-CPU baseline (the first pattern the
    /// exhaustive strategy measures, matching the convention that every
    /// search measures the baseline first).
    pub fn from_index(len: usize, idx: usize) -> Self {
        assert!(len < usize::BITS as usize, "space too wide to index");
        Self {
            bits: (0..len).map(|i| (idx >> i) & 1 == 1).collect(),
        }
    }

    /// Uniform random pattern with per-bit probability `p`.
    pub fn random(len: usize, p: f64, rng: &mut Pcg32) -> Self {
        Self {
            bits: (0..len).map(|_| rng.chance(p)).collect(),
        }
    }

    /// Number of offloaded loops.
    pub fn ones(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Length of the genome.
    pub fn len(&self) -> usize {
        self.bits.len()
    }

    /// Is the genome empty?
    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// Split a plan genome into `(loop genes, block genes)` given the
    /// number of leading loop genes. Assumes the classic 1-bit-per-gene
    /// layout; widened alphabets go through [`Genome::plan_split_n`].
    pub fn plan_split(&self, n_loops: usize) -> (&[bool], &[bool]) {
        self.plan_split_n(n_loops, 1)
    }

    /// Split a plan genome into `(loop genes, block genes)` when each
    /// gene spans `bits_per_gene` bits (mixed-destination genomes use 2:
    /// a destination code per gene). The block genes start at bit
    /// `n_loops * bits_per_gene`, NOT at bit `n_loops` — using
    /// [`Genome::plan_split`] on a widened genome mis-slices the layout.
    pub fn plan_split_n(&self, n_loops: usize, bits_per_gene: usize) -> (&[bool], &[bool]) {
        assert!(bits_per_gene > 0, "genes must span at least one bit");
        assert!(
            self.bits.len() % bits_per_gene == 0,
            "genome length {} is not a whole number of {bits_per_gene}-bit genes",
            self.bits.len()
        );
        let split = n_loops * bits_per_gene;
        assert!(split <= self.bits.len(), "more loop genes than bits");
        self.bits.split_at(split)
    }

    /// Number of active block destination genes (bits after the first
    /// `n_loops` loop genes). 1-bit-per-gene layout; see
    /// [`Genome::block_ones_n`] for widened alphabets.
    pub fn block_ones(&self, n_loops: usize) -> usize {
        self.block_ones_n(n_loops, 1)
    }

    /// Number of active block genes when each gene spans `bits_per_gene`
    /// bits: a block gene is active when ANY of its bits is set (code
    /// != 0), so this counts substituted blocks, not raw one-bits.
    pub fn block_ones_n(&self, n_loops: usize, bits_per_gene: usize) -> usize {
        self.plan_split_n(n_loops, bits_per_gene)
            .1
            .chunks(bits_per_gene)
            .filter(|gene| gene.iter().any(|&b| b))
            .count()
    }

    /// Hamming distance to another genome.
    pub fn distance(&self, other: &Genome) -> usize {
        assert_eq!(self.len(), other.len());
        self.bits
            .iter()
            .zip(&other.bits)
            .filter(|(a, b)| a != b)
            .count()
    }
}

impl std::fmt::Display for Genome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for &b in &self.bits {
            write!(f, "{}", if b { '1' } else { '0' })?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        assert_eq!(Genome::zeros(4).to_string(), "0000");
        assert_eq!(Genome::single(4, 2).to_string(), "0010");
        assert_eq!(Genome::single(4, 2).ones(), 1);
    }

    #[test]
    fn from_index_enumerates_the_space() {
        assert_eq!(Genome::from_index(4, 0), Genome::zeros(4));
        assert_eq!(Genome::from_index(4, 1).to_string(), "1000");
        assert_eq!(Genome::from_index(4, 0b1010).to_string(), "0101");
        assert_eq!(Genome::from_index(4, 15).ones(), 4);
        // Distinct indices give distinct genomes.
        let all: Vec<Genome> = (0..16).map(|i| Genome::from_index(4, i)).collect();
        for i in 0..16 {
            for j in (i + 1)..16 {
                assert_ne!(all[i], all[j]);
            }
        }
    }

    #[test]
    fn random_respects_probability() {
        let mut rng = Pcg32::seed_from_u64(1);
        let mut total = 0;
        for _ in 0..200 {
            total += Genome::random(16, 0.25, &mut rng).ones();
        }
        let frac = total as f64 / (200.0 * 16.0);
        assert!((frac - 0.25).abs() < 0.05, "frac {frac}");
    }

    #[test]
    fn plan_split_and_block_ones() {
        let g = Genome {
            bits: vec![true, false, false, true, true],
        };
        let (loops, blocks) = g.plan_split(3);
        assert_eq!(loops, &[true, false, false]);
        assert_eq!(blocks, &[true, true]);
        assert_eq!(g.block_ones(3), 2);
        assert_eq!(g.block_ones(5), 0, "loop-only view has no block genes");
    }

    #[test]
    fn widened_split_offsets_are_pinned() {
        // 3 loops + 2 blocks at 2 bits per gene = 10 bits. The block
        // genes start at bit 6 (= 3 * 2), not bit 3 — the regression the
        // 1-bit accessors would silently introduce on a widened genome.
        let g = Genome {
            bits: vec![
                true, false, // loop 0, code 1
                false, true, // loop 1, code 2
                false, false, // loop 2, code 0
                true, true, // block 0, code 3
                false, false, // block 1, code 0
            ],
        };
        let (loops, blocks) = g.plan_split_n(3, 2);
        assert_eq!(loops.len(), 6, "loop genes end at bit n_loops * 2");
        assert_eq!(blocks.len(), 4);
        assert_eq!(blocks, &[true, true, false, false]);
        assert_eq!(g.block_ones_n(3, 2), 1, "one active block, not two set bits");
        assert_eq!(g.block_ones_n(5, 2), 0, "gene-only view has no block genes");
        // The naive 1-bit split on the same genome lands mid-gene —
        // pinned here to document what the widened accessors fix.
        let (naive_loops, _) = g.plan_split(3);
        assert_eq!(naive_loops.len(), 3);
        // 1-bit accessors stay the trivial specialization.
        let h = Genome {
            bits: vec![true, false, false, true, true],
        };
        assert_eq!(h.plan_split(3), h.plan_split_n(3, 1));
        assert_eq!(h.block_ones(3), h.block_ones_n(3, 1));
    }

    #[test]
    fn distance_counts_differing_bits() {
        let a = Genome {
            bits: vec![true, false, true, false],
        };
        let b = Genome {
            bits: vec![true, true, false, false],
        };
        assert_eq!(a.distance(&b), 2);
        assert_eq!(a.distance(&a), 0);
    }
}
