//! The genetic-algorithm strategy of the paper's §3.1 GPU flow: genomes
//! are offload bit-patterns, the guide value is the measured evaluation
//! value `t^(-1/2)·p^(-1/2)`, and evolution runs generation by generation
//! with elitism, selection, crossover and mutation. Moved — not rewritten
//! — from the old `ga::engine`: same operators, same RNG stream, same
//! measurement order, so a GA search is bit-identical to the pre-Pareto
//! engine at the same seed. Every distinct pattern is measured at most
//! once ([`super::Archive`]).

use super::crossover::Crossover;
use super::genome::Genome;
use super::mutate::mutate;
use super::select::Selection;
use super::strategy::{SearchCtx, Strategy};
use crate::util::prng::Pcg32;
use crate::Result;

/// GA hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct GaConfig {
    /// Population size.
    pub population: usize,
    /// Generations to run.
    pub generations: usize,
    /// Probability a parent pair is crossed (else cloned).
    pub crossover_rate: f64,
    /// Per-bit mutation probability.
    pub mutation_rate: f64,
    /// Individuals copied unchanged to the next generation.
    pub elite: usize,
    /// Selection operator.
    pub selection: Selection,
    /// Crossover operator.
    pub crossover: Crossover,
    /// Initial per-bit 1-probability (sparse starts help: most loops
    /// should stay on the CPU).
    pub init_ones_p: f64,
}

impl Default for GaConfig {
    fn default() -> Self {
        Self {
            population: 16,
            generations: 20,
            crossover_rate: 0.9,
            mutation_rate: 0.05,
            elite: 2,
            selection: Selection::Roulette,
            crossover: Crossover::TwoPoint,
            init_ones_p: 0.25,
        }
    }
}

/// The §3.1 GA as a pluggable [`Strategy`].
#[derive(Debug, Clone, Copy)]
pub struct GaStrategy {
    /// Hyper-parameters.
    pub cfg: GaConfig,
}

impl Strategy for GaStrategy {
    fn name(&self) -> &'static str {
        "ga"
    }

    fn search(&self, ctx: &mut SearchCtx<'_>) -> Result<()> {
        let cfg = &self.cfg;
        let len = ctx.genome_len();
        assert!(cfg.population >= 2, "population too small");
        let mut rng = Pcg32::seed_from_u64(ctx.seed());

        // Initial population: always include the all-CPU pattern (the safe
        // baseline the paper compares against) plus random sparse patterns.
        let mut pop: Vec<Genome> = Vec::with_capacity(cfg.population);
        pop.push(Genome::zeros(len));
        while pop.len() < cfg.population {
            pop.push(Genome::random(len, cfg.init_ones_p, &mut rng));
        }

        let mut best_value = f64::NEG_INFINITY;
        for generation in 0..cfg.generations {
            // Batch-measure the generation's distinct new genomes, read
            // everything through the archive (measure-once rule).
            let fitness = ctx.values(&pop);

            // Track the global best (strict improvement: a NaN fitness can
            // never become the best).
            for &f in &fitness {
                if f > best_value {
                    best_value = f;
                }
            }
            let mean = fitness.iter().sum::<f64>() / fitness.len() as f64;
            ctx.record(best_value, mean);

            if generation + 1 == cfg.generations {
                break;
            }

            // Elitism: carry the top `elite` individuals. `total_cmp` is a
            // total order, so a NaN fitness (e.g. a failed trial scoring
            // NaN) sorts deterministically instead of panicking the old
            // `partial_cmp(..).unwrap()`.
            let mut order: Vec<usize> = (0..pop.len()).collect();
            order.sort_by(|&a, &b| fitness[b].total_cmp(&fitness[a]));
            let mut next: Vec<Genome> = order
                .iter()
                .take(cfg.elite.min(pop.len()))
                .map(|&i| pop[i].clone())
                .collect();

            // Offspring.
            while next.len() < cfg.population {
                let pa = cfg.selection.pick(&fitness, &mut rng);
                let pb = cfg.selection.pick(&fitness, &mut rng);
                let (mut c1, mut c2) = if rng.chance(cfg.crossover_rate) {
                    cfg.crossover.apply(&pop[pa], &pop[pb], &mut rng)
                } else {
                    (pop[pa].clone(), pop[pb].clone())
                };
                mutate(&mut c1, cfg.mutation_rate, &mut rng);
                mutate(&mut c2, cfg.mutation_rate, &mut rng);
                next.push(c1);
                if next.len() < cfg.population {
                    next.push(c2);
                }
            }
            pop = next;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::objective::{FitnessSpec, Objectives};
    use crate::search::strategy::{run_strategy, run_synthetic, SearchResult};

    fn ga(cfg: GaConfig) -> GaStrategy {
        GaStrategy { cfg }
    }

    fn run_scalar(
        len: usize,
        cfg: &GaConfig,
        seed: u64,
        score: impl FnMut(&Genome) -> f64,
    ) -> SearchResult {
        run_synthetic(&ga(*cfg), len, seed, score).unwrap()
    }

    /// OneMax: score = number of ones — the GA must find all-ones.
    #[test]
    fn solves_onemax() {
        let cfg = GaConfig {
            population: 24,
            generations: 40,
            ..Default::default()
        };
        let r = run_scalar(16, &cfg, 42, |g| g.ones() as f64);
        assert_eq!(r.best.ones(), 16, "best {}", r.best);
        assert_eq!(r.best_objectives, Objectives::synthetic(16.0));
    }

    /// Deceptive target: only one specific pattern is good.
    #[test]
    fn finds_needle_with_enough_budget() {
        let target = Genome {
            bits: vec![true, false, true, true, false, false, true, false],
        };
        let t = target.clone();
        let cfg = GaConfig {
            population: 30,
            generations: 60,
            mutation_rate: 0.08,
            ..Default::default()
        };
        let r = run_scalar(8, &cfg, 7, move |g| {
            let d = g.distance(&t) as f64;
            (8.0 - d) * (8.0 - d)
        });
        assert_eq!(r.best, target);
    }

    #[test]
    fn best_is_monotone_nondecreasing() {
        let cfg = GaConfig::default();
        let r = run_scalar(12, &cfg, 3, |g| g.ones() as f64 * 0.1);
        for w in r.history.windows(2) {
            assert!(w[1].best >= w[0].best);
        }
        assert_eq!(r.history.len(), cfg.generations);
    }

    #[test]
    fn archive_limits_measurements() {
        let cfg = GaConfig {
            population: 16,
            generations: 30,
            ..Default::default()
        };
        let mut calls = 0usize;
        let r = run_scalar(6, &cfg, 11, |g| {
            calls += 1;
            g.ones() as f64
        });
        // 6-bit space has 64 patterns; eval calls can never exceed that.
        assert!(calls <= 64, "calls {calls}");
        assert_eq!(calls, r.measured);
        assert!(r.cache_hits > 0, "revisits must hit the archive");
    }

    #[test]
    fn deterministic_for_same_seed() {
        let cfg = GaConfig::default();
        let a = run_scalar(10, &cfg, 5, |g| g.ones() as f64);
        let b = run_scalar(10, &cfg, 5, |g| g.ones() as f64);
        assert_eq!(a.best, b.best);
        assert_eq!(a.measured, b.measured);
    }

    #[test]
    fn all_cpu_baseline_always_measured() {
        let cfg = GaConfig {
            population: 4,
            generations: 2,
            ..Default::default()
        };
        let mut saw_zero = false;
        run_scalar(5, &cfg, 9, |g| {
            if g.ones() == 0 {
                saw_zero = true;
            }
            1.0
        });
        assert!(saw_zero);
    }

    /// Regression (NaN-unsafe elitism): the old engine sorted with
    /// `partial_cmp(..).unwrap()` and panicked the moment any fitness was
    /// NaN. The `total_cmp` sort must survive a NaN-producing eval, and a
    /// NaN pattern must never be selected as the best.
    #[test]
    fn nan_fitness_does_not_panic_and_is_never_best() {
        let cfg = GaConfig {
            population: 14,
            generations: 18,
            init_ones_p: 0.5,
            mutation_rate: 0.1,
            ..Default::default()
        };
        let nan = Objectives {
            time_s: f64::NAN,
            energy_ws: f64::NAN,
            peak_w: f64::NAN,
            measured_peak_w: f64::NAN,
            mean_w: f64::NAN,
            timed_out: false,
        };
        let r = run_strategy(&ga(cfg), 6, FitnessSpec::paper(), 11, |batch| {
            batch
                .iter()
                .map(|g| {
                    if g.ones() == 2 {
                        nan
                    } else {
                        Objectives::synthetic(g.ones() as f64)
                    }
                })
                .collect()
        })
        .unwrap();
        // The all-CPU baseline (finite, value 1.0) is always measured, so
        // the best is finite and never a NaN-ring pattern.
        assert!(r.best_value.is_finite(), "best {}", r.best_value);
        assert!(r.best_value >= 1.0);
        assert_ne!(r.best.ones(), 2, "NaN pattern selected as best");
        // The front only carries finite points.
        for s in &r.front.points {
            assert!(s.objectives.is_finite());
            assert_ne!(s.genome.ones(), 2);
        }
    }
}
