//! Mutation operator: per-bit flip.

use super::genome::Genome;
use crate::util::prng::Pcg32;

/// Flip each bit independently with probability `rate`.
pub fn mutate(g: &mut Genome, rate: f64, rng: &mut Pcg32) {
    for b in &mut g.bits {
        if rng.chance(rate) {
            *b = !*b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_is_identity() {
        let mut rng = Pcg32::seed_from_u64(1);
        let mut g = Genome::random(32, 0.5, &mut rng);
        let before = g.clone();
        mutate(&mut g, 0.0, &mut rng);
        assert_eq!(g, before);
    }

    #[test]
    fn one_rate_flips_everything() {
        let mut rng = Pcg32::seed_from_u64(2);
        let mut g = Genome::zeros(16);
        mutate(&mut g, 1.0, &mut rng);
        assert_eq!(g.ones(), 16);
    }

    #[test]
    fn expected_flip_count_matches_rate() {
        let mut rng = Pcg32::seed_from_u64(3);
        let mut flips = 0usize;
        for _ in 0..500 {
            let mut g = Genome::zeros(20);
            mutate(&mut g, 0.1, &mut rng);
            flips += g.ones();
        }
        let frac = flips as f64 / (500.0 * 20.0);
        assert!((frac - 0.1).abs() < 0.02, "frac {frac}");
    }
}
