//! Parent selection operators.

use crate::util::prng::Pcg32;

/// Selection strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// Fitness-proportional (roulette-wheel) selection — what (33) used;
    /// degenerates gracefully when all fitnesses are equal.
    Roulette,
    /// Tournament of size `k` (more selection pressure, scale-free).
    Tournament(usize),
}

impl Selection {
    /// Pick one parent index given the population fitness values.
    pub fn pick(&self, fitness: &[f64], rng: &mut Pcg32) -> usize {
        assert!(!fitness.is_empty());
        match *self {
            Selection::Roulette => {
                let total: f64 = fitness.iter().map(|f| f.max(0.0)).sum();
                if total <= 0.0 {
                    return rng.below_usize(fitness.len());
                }
                let mut target = rng.next_f64() * total;
                for (i, f) in fitness.iter().enumerate() {
                    target -= f.max(0.0);
                    if target <= 0.0 {
                        return i;
                    }
                }
                fitness.len() - 1
            }
            Selection::Tournament(k) => {
                let k = k.max(1);
                let mut best = rng.below_usize(fitness.len());
                for _ in 1..k {
                    let c = rng.below_usize(fitness.len());
                    if fitness[c] > fitness[best] {
                        best = c;
                    }
                }
                best
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roulette_prefers_fitter() {
        let mut rng = Pcg32::seed_from_u64(1);
        let fitness = [1.0, 9.0];
        let n = 10_000;
        let hits1 = (0..n)
            .filter(|_| Selection::Roulette.pick(&fitness, &mut rng) == 1)
            .count();
        let frac = hits1 as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.03, "frac {frac}");
    }

    #[test]
    fn tournament_prefers_fitter() {
        let mut rng = Pcg32::seed_from_u64(2);
        let fitness = [0.1, 0.2, 0.9, 0.3];
        let hits = (0..2_000)
            .filter(|_| Selection::Tournament(3).pick(&fitness, &mut rng) == 2)
            .count();
        assert!(hits > 1_000, "hits {hits}");
    }

    #[test]
    fn degenerate_all_zero_fitness_is_uniform() {
        let mut rng = Pcg32::seed_from_u64(3);
        let fitness = [0.0, 0.0, 0.0];
        let mut seen = [0usize; 3];
        for _ in 0..3_000 {
            seen[Selection::Roulette.pick(&fitness, &mut rng)] += 1;
        }
        assert!(seen.iter().all(|&c| c > 800), "{seen:?}");
    }

    #[test]
    fn indices_always_in_bounds() {
        let mut rng = Pcg32::seed_from_u64(4);
        let fitness = [0.5, 0.1];
        for _ in 0..1000 {
            assert!(Selection::Roulette.pick(&fitness, &mut rng) < 2);
            assert!(Selection::Tournament(5).pick(&fitness, &mut rng) < 2);
        }
    }
}
