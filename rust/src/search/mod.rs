//! The pluggable multi-objective search layer (successor of the old
//! single-strategy `ga` module).
//!
//! The paper's evaluation value `t^(-1/2)·p^(-1/2)` (§3.1) is, per §3.3,
//! only one operator's *scalarization* — "the formula must be set
//! differently per business operator". This layer therefore separates the
//! three concerns the old GA engine fused:
//!
//! * **Objectives** ([`objective`]) — a measured trial is a *vector*
//!   `(time, energy, peak draw)`; [`FitnessSpec`] is one scalarization,
//!   applied *after* the search picks up a non-dominated front
//!   (scalarization-last).
//! * **Strategies** ([`strategy`]) — a [`Strategy`] proposes pattern
//!   batches and observes archived objective vectors. Three
//!   implementations: the §3.1 genetic algorithm ([`ga`], moved — not
//!   rewritten — from the old engine, bit-identical per seed), an
//!   [`Exhaustive`] sweep for small spaces (the FPGA flow's
//!   few-candidates reality, Yamato 2020) and a deterministic
//!   [`Annealing`] hill-climber as a cheap ablation arm.
//! * **Pareto dominance** ([`pareto`]) — every search returns the
//!   non-dominated `(time × W·s × peak-W)` front alongside the
//!   guide-scalarized best, so different operators can pick different
//!   knee points from one search.
//!
//! The FPGA destination is the exception: under the default GA strategy
//! it routes to the paper's §3.2 narrowing funnel
//! ([`crate::offload::fpga_flow`]) instead of a generic [`Strategy`] —
//! hours-long OpenCL compiles make evolutionary measurement infeasible,
//! so candidates are narrowed by intensity, trip count and precompiled
//! resource fit before anything is measured.
//!
//! Operator scalarizations compose: [`FitnessSpec::with_watt_cap`] is the
//! §3.3 per-operator peak-draw constraint, and [`watt_sub_budget`]
//! derives that cap per job from a *fleet-wide* Watt budget (the
//! power-budget scheduler's admission headroom, DESIGN.md §10).
//!
//! Invariants carried over from the old engine: each distinct pattern is
//! measured at most once per search ([`Archive`]), evaluation batches
//! receive only first-occurrence novel genomes in request order, and every
//! strategy is deterministic per seed — so parallel trial evaluation and
//! cross-job measurement caching stay bit-reproducible (DESIGN.md §4, §9).

pub mod anneal;
pub mod crossover;
pub mod exhaustive;
pub mod ga;
pub mod genome;
pub mod mutate;
pub mod objective;
pub mod pareto;
pub mod select;
pub mod strategy;

pub use anneal::{AnnealConfig, Annealing};
pub use crossover::Crossover;
pub use exhaustive::Exhaustive;
pub use ga::{GaConfig, GaStrategy};
pub use genome::Genome;
pub use mutate::mutate;
pub use objective::{watt_sub_budget, FitnessSpec, Objectives, Scored};
pub use pareto::{dominates, ParetoFront};
pub use select::Selection;
pub use strategy::{
    run_strategy, run_synthetic, Archive, GenStats, SearchCtx, SearchResult, SearchStrategy,
    Strategy,
};
