//! Crossover operators over bit genomes.

use super::genome::Genome;
use crate::util::prng::Pcg32;

/// Crossover strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Crossover {
    /// Single cut point.
    OnePoint,
    /// Two cut points (segment swap).
    TwoPoint,
    /// Per-bit coin flip.
    Uniform,
}

impl Crossover {
    /// Produce two children from two parents.
    pub fn apply(&self, a: &Genome, b: &Genome, rng: &mut Pcg32) -> (Genome, Genome) {
        assert_eq!(a.len(), b.len());
        let n = a.len();
        if n < 2 {
            return (a.clone(), b.clone());
        }
        let mut c = a.bits.clone();
        let mut d = b.bits.clone();
        match *self {
            Crossover::OnePoint => {
                let cut = 1 + rng.below_usize(n - 1);
                for i in cut..n {
                    let t = c[i];
                    c[i] = d[i];
                    d[i] = t;
                }
            }
            Crossover::TwoPoint => {
                let mut p = 1 + rng.below_usize(n - 1);
                let mut q = 1 + rng.below_usize(n - 1);
                if p > q {
                    std::mem::swap(&mut p, &mut q);
                }
                for i in p..q {
                    let t = c[i];
                    c[i] = d[i];
                    d[i] = t;
                }
            }
            Crossover::Uniform => {
                for i in 0..n {
                    if rng.chance(0.5) {
                        let t = c[i];
                        c[i] = d[i];
                        d[i] = t;
                    }
                }
            }
        }
        (Genome { bits: c }, Genome { bits: d })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parents(n: usize) -> (Genome, Genome) {
        (
            Genome {
                bits: vec![true; n],
            },
            Genome {
                bits: vec![false; n],
            },
        )
    }

    /// Crossover must conserve the multiset of bits at each position.
    fn conserves(a: &Genome, b: &Genome, c: &Genome, d: &Genome) -> bool {
        (0..a.len()).all(|i| {
            let before = (a.bits[i] as u8) + (b.bits[i] as u8);
            let after = (c.bits[i] as u8) + (d.bits[i] as u8);
            before == after
        })
    }

    #[test]
    fn all_operators_conserve_bits() {
        let mut rng = Pcg32::seed_from_u64(1);
        for op in [Crossover::OnePoint, Crossover::TwoPoint, Crossover::Uniform] {
            for _ in 0..100 {
                let a = Genome::random(16, 0.4, &mut rng);
                let b = Genome::random(16, 0.6, &mut rng);
                let (c, d) = op.apply(&a, &b, &mut rng);
                assert!(conserves(&a, &b, &c, &d), "{op:?}");
            }
        }
    }

    #[test]
    fn one_point_creates_mixed_children() {
        let mut rng = Pcg32::seed_from_u64(2);
        let (a, b) = parents(16);
        let (c, _) = Crossover::OnePoint.apply(&a, &b, &mut rng);
        let ones = c.ones();
        assert!(ones > 0 && ones < 16, "child should mix: {c}");
    }

    #[test]
    fn short_genomes_pass_through() {
        let mut rng = Pcg32::seed_from_u64(3);
        let (a, b) = parents(1);
        let (c, d) = Crossover::TwoPoint.apply(&a, &b, &mut rng);
        assert_eq!(c, a);
        assert_eq!(d, b);
    }
}
