//! Pareto dominance over [`Objectives`] and non-dominated front
//! construction.
//!
//! All three axes — processing time, consumed W·s, exact peak draw — are
//! minimized. The front is what a search hands back before any operator
//! scalarization is applied: different [`FitnessSpec`]s pick different
//! knee points from the *same* measured front, so changing the operator's
//! formula (§3.3) never requires re-measuring anything.

use super::genome::Genome;
use super::objective::{FitnessSpec, Objectives, Scored};

/// Does `a` Pareto-dominate `b`? True iff `a` is no worse on every axis
/// (time, energy, peak) and strictly better on at least one. Any
/// comparison against a NaN axis is false, so NaN points neither dominate
/// nor are dominated (fronts exclude them explicitly).
pub fn dominates(a: &Objectives, b: &Objectives) -> bool {
    let no_worse =
        a.time_s <= b.time_s && a.energy_ws <= b.energy_ws && a.peak_w <= b.peak_w;
    let better =
        a.time_s < b.time_s || a.energy_ws < b.energy_ws || a.peak_w < b.peak_w;
    no_worse && better
}

/// The non-dominated subset of a search's measured points.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParetoFront {
    /// Front members, sorted by ascending time (ties: energy, then peak,
    /// then genome bits) — the presentation order reports use.
    pub points: Vec<Scored>,
}

impl ParetoFront {
    /// Build the front of `points`: drop non-finite entries, sort, and
    /// keep the non-dominated ones. With the sort order above, a later
    /// point can never dominate an earlier one, so a single append-only
    /// sweep against the growing front suffices (fast even for the 2^16
    /// exhaustive archive — front sizes stay small).
    pub fn of(points: &[Scored]) -> Self {
        let mut pts: Vec<Scored> = points
            .iter()
            .filter(|s| s.objectives.is_finite())
            .cloned()
            .collect();
        pts.sort_by(|x, y| {
            x.objectives
                .time_s
                .total_cmp(&y.objectives.time_s)
                .then_with(|| x.objectives.energy_ws.total_cmp(&y.objectives.energy_ws))
                .then_with(|| x.objectives.peak_w.total_cmp(&y.objectives.peak_w))
                .then_with(|| x.genome.bits.cmp(&y.genome.bits))
        });
        let mut front: Vec<Scored> = Vec::new();
        for p in pts {
            if !front.iter().any(|f| dominates(&f.objectives, &p.objectives)) {
                front.push(p);
            }
        }
        Self { points: front }
    }

    /// Number of non-dominated points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// Is the front empty?
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Is a pattern on the front?
    pub fn contains(&self, genome: &Genome) -> bool {
        self.points.iter().any(|s| &s.genome == genome)
    }

    /// The operator's knee point: the front member with the highest
    /// scalarized value (strict improvement — the first of equal-valued
    /// points in front order wins, deterministically).
    pub fn knee(&self, spec: &FitnessSpec) -> Option<&Scored> {
        let mut best: Option<(&Scored, f64)> = None;
        for s in &self.points {
            let v = spec.scalarize(&s.objectives);
            match best {
                None => best = Some((s, v)),
                // Strict improvement only — a NaN value never wins.
                Some((_, bv)) if v > bv => best = Some((s, v)),
                _ => {}
            }
        }
        best.map(|(s, _)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(bits: &[bool], t: f64, e: f64, p: f64) -> Scored {
        Scored {
            genome: Genome {
                bits: bits.to_vec(),
            },
            objectives: Objectives {
                time_s: t,
                energy_ws: e,
                peak_w: p,
                measured_peak_w: p,
                mean_w: e / t,
                timed_out: false,
            },
        }
    }

    #[test]
    fn dominance_basics() {
        let a = point(&[true], 1.0, 100.0, 120.0);
        let b = point(&[false], 2.0, 200.0, 130.0);
        let c = point(&[true, true], 0.5, 300.0, 120.0);
        assert!(dominates(&a.objectives, &b.objectives));
        assert!(!dominates(&b.objectives, &a.objectives));
        // Trade-off points do not dominate each other.
        assert!(!dominates(&a.objectives, &c.objectives));
        assert!(!dominates(&c.objectives, &a.objectives));
        // A point never dominates itself (no strict improvement).
        assert!(!dominates(&a.objectives, &a.objectives));
    }

    #[test]
    fn front_keeps_each_axis_minimum_and_drops_dominated() {
        let pts = vec![
            point(&[false, false], 14.0, 1690.0, 121.0), // baseline: min peak
            point(&[true, false], 2.0, 220.0, 129.0),    // min energy
            point(&[false, true], 1.5, 400.0, 233.0),    // min time
            point(&[true, true], 3.0, 500.0, 233.0),     // dominated by both offloads
        ];
        let front = ParetoFront::of(&pts);
        assert_eq!(front.len(), 3);
        assert!(front.contains(&pts[0].genome), "min-peak baseline survives");
        assert!(front.contains(&pts[1].genome), "min-energy point survives");
        assert!(front.contains(&pts[2].genome), "min-time point survives");
        assert!(!front.contains(&pts[3].genome), "dominated point dropped");
        // Pairwise non-dominated.
        for a in &front.points {
            for b in &front.points {
                if a.genome != b.genome {
                    assert!(!dominates(&a.objectives, &b.objectives));
                }
            }
        }
        // Sorted by ascending time.
        for w in front.points.windows(2) {
            assert!(w[0].objectives.time_s <= w[1].objectives.time_s);
        }
    }

    #[test]
    fn non_finite_points_are_excluded() {
        let mut bad = point(&[true], 1.0, 100.0, 120.0);
        bad.objectives.energy_ws = f64::NAN;
        let good = point(&[false], 2.0, 200.0, 130.0);
        let front = ParetoFront::of(&[bad.clone(), good.clone()]);
        assert_eq!(front.len(), 1);
        assert!(front.contains(&good.genome));
        assert!(!front.contains(&bad.genome));
    }

    #[test]
    fn knee_follows_the_scalarization() {
        let pts = vec![
            point(&[false, false], 14.0, 1690.0, 121.0),
            point(&[true, false], 2.0, 220.0, 129.0),
            point(&[false, true], 1.5, 400.0, 233.0),
        ];
        let front = ParetoFront::of(&pts);
        // Paper spec: value = (t·p)^-1/2 = energy^-1/2 → min-energy wins.
        let knee = front.knee(&FitnessSpec::paper()).unwrap();
        assert_eq!(knee.genome, pts[1].genome);
        // Time-only spec: the fastest point wins instead.
        let knee_t = front.knee(&FitnessSpec::time_only()).unwrap();
        assert_eq!(knee_t.genome, pts[2].genome);
        // A Watt cap moves the knee to a cap-respecting point.
        let capped = FitnessSpec::paper().with_watt_cap(125.0);
        let knee_c = front.knee(&capped).unwrap();
        assert_eq!(knee_c.genome, pts[0].genome);
        assert!(ParetoFront::default().knee(&FitnessSpec::paper()).is_none());
    }
}
