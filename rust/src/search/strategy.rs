//! The pluggable search driver: a [`Strategy`] proposes pattern batches
//! (ask), a [`SearchCtx`] measures them through the measure-once
//! [`Archive`] and hands back objective vectors (tell), and
//! [`run_strategy`] assembles the outcome — the guide-scalarized best,
//! the non-dominated Pareto front and the convergence history.
//!
//! Determinism contract (DESIGN.md §4, §9): a strategy must derive all of
//! its randomness from the seed in the context, and the evaluation hook
//! receives only *first-occurrence novel* genomes in request order — so
//! the measurement sequence, the per-trial RNG streams and the shared
//! [`MeasureCache`](crate::util::measure_cache::MeasureCache) behavior
//! are bit-reproducible, and the GA strategy reproduces the old engine's
//! results exactly.

use super::anneal::AnnealConfig;
use super::genome::Genome;
use super::objective::{FitnessSpec, Objectives, Scored};
use super::pareto::ParetoFront;
use crate::{Error, Result};
use std::collections::{HashMap, HashSet};

/// Per-round statistics (one GA generation, one exhaustive chunk, one
/// annealing step) — the Fig. 2 bench's convergence series.
#[derive(Debug, Clone, Copy)]
pub struct GenStats {
    /// Round index (0-based; "generation" for the GA).
    pub generation: usize,
    /// Best guide-scalarized value seen so far (monotone non-decreasing).
    pub best: f64,
    /// Mean guide value across the round.
    pub mean: f64,
    /// Distinct patterns measured so far (cumulative search cost).
    pub measured: usize,
}

/// Measure-once archive: measurement trials in the verification
/// environment are expensive (compile + run + power capture), so each
/// distinct pattern is measured once *within a search* — revisited
/// genomes are answered from the archive. The archive doubles as the
/// search log (every pattern ever measured, in first-measured order) and
/// is the engine-local half of a two-level scheme: cross-job and
/// cross-invocation deduplication lives in the shared, thread-safe
/// [`crate::util::measure_cache::MeasureCache`] (DESIGN.md §7).
#[derive(Debug, Default)]
pub struct Archive {
    order: Vec<Genome>,
    map: HashMap<Vec<bool>, Objectives>,
    hits: u64,
}

impl Archive {
    /// Empty archive.
    pub fn new() -> Self {
        Self::default()
    }

    /// Is the pattern already measured?
    pub fn contains(&self, g: &Genome) -> bool {
        self.map.contains_key(&g.bits)
    }

    /// Measured objectives of a pattern, if any.
    pub fn get(&self, g: &Genome) -> Option<&Objectives> {
        self.map.get(&g.bits)
    }

    /// Number of distinct patterns measured.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    /// Is the archive empty?
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Archive hits (revisited patterns — measurements *saved*).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// The full search log in first-measured order.
    pub fn scored(&self) -> Vec<Scored> {
        self.order
            .iter()
            .map(|g| Scored {
                genome: g.clone(),
                objectives: self.map[&g.bits],
            })
            .collect()
    }
}

/// What a running strategy sees: the genome width, the seed, the guide
/// scalarization, the archive, and the batched ask/tell hook.
pub struct SearchCtx<'a> {
    len: usize,
    seed: u64,
    guide: FitnessSpec,
    archive: Archive,
    history: Vec<GenStats>,
    eval: &'a mut dyn FnMut(&[Genome]) -> Vec<Objectives>,
}

impl SearchCtx<'_> {
    /// Genome width (bits per pattern).
    pub fn genome_len(&self) -> usize {
        self.len
    }

    /// The search seed (strategies derive all randomness from it).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The guide scalarization (what [`SearchCtx::values`] applies).
    pub fn guide(&self) -> &FitnessSpec {
        &self.guide
    }

    /// The measure-once archive (read access; the search log).
    pub fn archive(&self) -> &Archive {
        &self.archive
    }

    /// Measure a batch of genomes — repeats welcome. Novel patterns are
    /// forwarded to the evaluation hook in first-occurrence order
    /// (deduplicated with a hash set, not a quadratic scan) and archived;
    /// revisits are answered from the archive and counted as hits.
    /// Returns the objective vectors aligned with `genomes`.
    pub fn measure(&mut self, genomes: &[Genome]) -> Vec<Objectives> {
        let mut novel: Vec<Genome> = Vec::new();
        let mut seen: HashSet<&[bool]> = HashSet::new();
        for g in genomes {
            debug_assert_eq!(g.len(), self.len, "genome width mismatch");
            if self.archive.map.contains_key(&g.bits) || !seen.insert(&g.bits) {
                self.archive.hits += 1;
            } else {
                novel.push(g.clone());
            }
        }
        if !novel.is_empty() {
            let values = (self.eval)(&novel);
            assert_eq!(values.len(), novel.len(), "eval batch arity");
            for (g, o) in novel.into_iter().zip(values) {
                self.archive.map.insert(g.bits.clone(), o);
                self.archive.order.push(g);
            }
        }
        genomes
            .iter()
            .map(|g| self.archive.map[&g.bits])
            .collect()
    }

    /// Guide-scalarized values of a batch (see [`SearchCtx::measure`]).
    pub fn values(&mut self, genomes: &[Genome]) -> Vec<f64> {
        let guide = self.guide;
        self.measure(genomes)
            .iter()
            .map(|o| guide.scalarize(o))
            .collect()
    }

    /// Append one convergence round to the history.
    pub fn record(&mut self, best: f64, mean: f64) {
        crate::obs::metrics::add("search.generations", 1);
        crate::obs::metrics::observe("search.gen_measured", self.archive.len() as u64);
        self.history.push(GenStats {
            generation: self.history.len(),
            best,
            mean,
            measured: self.archive.len(),
        });
    }
}

/// A pattern-search strategy: proposes batches of genomes to the context
/// and observes their measured objective vectors until its budget is
/// spent. Implementations: [`super::GaStrategy`] (the paper's §3.1
/// evolutionary search), [`super::Exhaustive`] (small spaces),
/// [`super::Annealing`] (deterministic hill-climbing ablation).
pub trait Strategy {
    /// Short name for reports and the CLI.
    fn name(&self) -> &'static str;

    /// Drive the search to completion over `ctx`.
    fn search(&self, ctx: &mut SearchCtx<'_>) -> Result<()>;
}

/// Outcome of a strategy run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Which strategy ran.
    pub strategy: &'static str,
    /// Best genome under the guide scalarization (strict improvement, so
    /// the first-measured of equal-valued patterns wins — the old GA
    /// engine's selection rule, preserved bit-for-bit).
    pub best: Genome,
    /// Its guide value.
    pub best_value: f64,
    /// Its objective vector.
    pub best_objectives: Objectives,
    /// Non-dominated `(time × W·s × peak-W)` front of every measured
    /// pattern — the scalarization-free product of the search.
    pub front: ParetoFront,
    /// Convergence history (one entry per strategy round).
    pub history: Vec<GenStats>,
    /// Distinct patterns measured (expensive verification trials run).
    pub measured: usize,
    /// Archive hits (revisits answered without re-measuring).
    pub cache_hits: u64,
}

/// Run a strategy over a `len`-bit pattern space. `eval_batch` receives
/// the distinct not-yet-measured genomes of each proposal batch, in
/// first-occurrence order, and returns their measured objectives — the
/// hook the offload flows use to run verification trials (concurrently on
/// the bounded scoped pool when enabled; results are bit-identical to
/// serial evaluation because trials are deterministic per pattern).
pub fn run_strategy(
    strategy: &dyn Strategy,
    len: usize,
    guide: FitnessSpec,
    seed: u64,
    mut eval_batch: impl FnMut(&[Genome]) -> Vec<Objectives>,
) -> Result<SearchResult> {
    if len == 0 {
        return Err(Error::Verify("empty genome space".into()));
    }
    let mut ctx = SearchCtx {
        len,
        seed,
        guide,
        archive: Archive::new(),
        history: Vec::new(),
        eval: &mut eval_batch,
    };
    {
        let _sp = crate::obs::span::span("search", strategy.name());
        strategy.search(&mut ctx)?;
    }
    let SearchCtx {
        archive, history, ..
    } = ctx;
    let entries = archive.scored();
    if entries.is_empty() {
        return Err(Error::Verify(format!(
            "strategy '{}' measured no patterns",
            strategy.name()
        )));
    }
    // Strict argmax in first-measured order (ties keep the earlier
    // pattern; an all-NaN landscape keeps the first entry at -inf).
    let mut best = &entries[0];
    let mut best_value = f64::NEG_INFINITY;
    for s in &entries {
        let v = guide.scalarize(&s.objectives);
        if v > best_value {
            best_value = v;
            best = s;
        }
    }
    let front = ParetoFront::of(&entries);
    crate::obs::metrics::add("search.measured", archive.len() as u64);
    crate::obs::metrics::add("search.front_points", front.len() as u64);
    crate::obs::metrics::gauge_set(
        "search.evals_per_front_point",
        archive.len() as f64 / front.len().max(1) as f64,
    );
    Ok(SearchResult {
        strategy: strategy.name(),
        best: best.genome.clone(),
        best_value,
        best_objectives: best.objectives,
        front,
        history,
        measured: archive.len(),
        cache_hits: archive.hits(),
    })
}

/// Drive a strategy over a synthetic scalar landscape: `score` is mapped
/// through [`Objectives::synthetic`] (paper-scalarization `sqrt(1+score)`,
/// strictly monotone). For engine tests and throughput benches — real
/// searches measure [`Objectives`] in the verification environment.
pub fn run_synthetic(
    strategy: &dyn Strategy,
    len: usize,
    seed: u64,
    mut score: impl FnMut(&Genome) -> f64,
) -> Result<SearchResult> {
    run_strategy(strategy, len, FitnessSpec::paper(), seed, |batch| {
        batch
            .iter()
            .map(|g| Objectives::synthetic(score(g)))
            .collect()
    })
}

/// Strategy selector carried by flow configurations and the CLI
/// (`--strategy ga|exhaustive|anneal`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum SearchStrategy {
    /// §3.1 genetic algorithm (hyper-parameters come from the flow's
    /// [`GaConfig`](super::GaConfig)). The default — and, for the FPGA
    /// destination, the marker that selects the §3.2 narrowing funnel.
    #[default]
    Ga,
    /// Exhaustive enumeration of the whole pattern space (small spaces —
    /// the FPGA flow's few-candidates reality).
    Exhaustive {
        /// Refuse genome spaces wider than this many bits.
        max_bits: usize,
    },
    /// Deterministic simulated-annealing hill-climber (cheap ablation).
    Anneal(AnnealConfig),
}

impl SearchStrategy {
    /// CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            SearchStrategy::Ga => "ga",
            SearchStrategy::Exhaustive { .. } => "exhaustive",
            SearchStrategy::Anneal(_) => "anneal",
        }
    }

    /// Does this strategy route the FPGA destination through the paper's
    /// §3.2 narrowing funnel? Only the default GA does — compile-hour
    /// economics make evolution (and the funnel) the realistic FPGA
    /// search; an explicit exhaustive/anneal request drives the device
    /// model directly instead. The single owner of the routing rule the
    /// pipeline and the mixed flow both follow.
    pub fn uses_fpga_funnel(&self) -> bool {
        matches!(self, SearchStrategy::Ga)
    }

    /// Parse a CLI `--strategy` value into a default-configured strategy.
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "ga" => Some(SearchStrategy::Ga),
            "exhaustive" => Some(SearchStrategy::Exhaustive {
                max_bits: super::exhaustive::DEFAULT_MAX_BITS,
            }),
            "anneal" => Some(SearchStrategy::Anneal(AnnealConfig::default())),
            _ => None,
        }
    }

    /// Instantiate the strategy (the GA takes its hyper-parameters from
    /// `ga`; the others carry their own).
    pub fn build(&self, ga: &super::ga::GaConfig) -> Box<dyn Strategy> {
        match self {
            SearchStrategy::Ga => Box::new(super::ga::GaStrategy { cfg: *ga }),
            SearchStrategy::Exhaustive { max_bits } => Box::new(super::exhaustive::Exhaustive {
                max_bits: *max_bits,
                ..Default::default()
            }),
            SearchStrategy::Anneal(cfg) => Box::new(super::anneal::Annealing { cfg: *cfg }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted strategy that asks for fixed batches (with deliberate
    /// repeats) — exercises the archive contract without a real search.
    struct Scripted {
        batches: Vec<Vec<Genome>>,
    }

    impl Strategy for Scripted {
        fn name(&self) -> &'static str {
            "scripted"
        }
        fn search(&self, ctx: &mut SearchCtx<'_>) -> Result<()> {
            for b in &self.batches {
                let vals = ctx.values(b);
                let mean = vals.iter().sum::<f64>() / vals.len().max(1) as f64;
                let best = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                ctx.record(best, mean);
            }
            Ok(())
        }
    }

    fn g(bits: &[u8]) -> Genome {
        Genome {
            bits: bits.iter().map(|&b| b == 1).collect(),
        }
    }

    #[test]
    fn archive_dedups_in_first_occurrence_order() {
        let a = g(&[0, 0, 0]);
        let b = g(&[1, 0, 0]);
        let c = g(&[0, 1, 0]);
        let s = Scripted {
            // Batch 1 repeats `b` inline; batch 2 revisits `a` and `b`.
            batches: vec![vec![a.clone(), b.clone(), b.clone()], vec![b.clone(), c.clone(), a.clone()]],
        };
        let mut eval_log: Vec<String> = Vec::new();
        let r = run_strategy(&s, 3, FitnessSpec::paper(), 1, |batch| {
            batch
                .iter()
                .map(|g| {
                    eval_log.push(g.to_string());
                    Objectives::synthetic(g.ones() as f64)
                })
                .collect()
        })
        .unwrap();
        // Each distinct pattern measured exactly once, in first-occurrence
        // order; repeats hit the archive.
        assert_eq!(eval_log, vec!["000", "100", "010"]);
        assert_eq!(r.measured, 3);
        assert_eq!(r.cache_hits, 3, "b (twice) and a revisited");
        assert_eq!(r.history.len(), 2);
        // Strict argmax with first-measured tie-breaking: b and c tie at
        // one bit set; b was measured first.
        assert_eq!(r.best, b);
        let _ = (a, c);
    }

    #[test]
    fn front_and_best_come_from_the_archive() {
        let pts = vec![vec![g(&[0, 0]), g(&[1, 0]), g(&[0, 1]), g(&[1, 1])]];
        let s = Scripted { batches: pts };
        let r = run_synthetic(&s, 2, 1, |g| g.ones() as f64).unwrap();
        assert_eq!(r.best.ones(), 2);
        assert!(r.best_value > 0.0);
        assert_eq!(r.best_objectives, Objectives::synthetic(2.0));
        // Synthetic objectives: higher score → lower energy/peak at equal
        // time, so only the top scorer is non-dominated.
        assert_eq!(r.front.len(), 1);
        assert!(r.front.contains(&r.best));
    }

    #[test]
    fn empty_search_is_an_error() {
        let s = Scripted { batches: vec![] };
        let r = run_synthetic(&s, 4, 1, |_| 0.0);
        assert!(r.is_err());
        let zero = run_synthetic(&Scripted { batches: vec![] }, 0, 1, |_| 0.0);
        assert!(zero.is_err(), "zero-width space is rejected");
    }

    #[test]
    fn strategy_selector_round_trips_names() {
        for name in ["ga", "exhaustive", "anneal"] {
            let s = SearchStrategy::from_name(name).unwrap();
            assert_eq!(s.name(), name);
            assert_eq!(s.build(&super::super::GaConfig::default()).name(), name);
        }
        assert!(SearchStrategy::from_name("tabu").is_none());
        assert_eq!(SearchStrategy::default(), SearchStrategy::Ga);
    }
}
