//! Exhaustive strategy: measure the entire `2^len` pattern space.
//!
//! The FPGA-offloading flow (Yamato 2020) narrows to a handful of
//! candidates and then *measures every one of them* — a strategy the old
//! GA engine could not express. This is that strategy, generalized: for
//! spaces up to a configurable bit-width the optimum (and the exact
//! Pareto front) is found by enumeration, which also makes it the
//! ground-truth arm the strategy-parity tests compare the GA and the
//! annealer against.

use super::genome::Genome;
use super::strategy::{SearchCtx, Strategy};
use crate::{Error, Result};

/// Widest space the exhaustive strategy accepts by default: 16 bits —
/// MRI-Q's full candidate space (2^16 = 65,536 trials, cheap against the
/// simulated verification environment, unthinkable against real FPGA
/// compiles; the narrowing funnel exists for those).
pub const DEFAULT_MAX_BITS: usize = 16;

/// Exhaustive enumeration of the whole pattern space.
#[derive(Debug, Clone, Copy)]
pub struct Exhaustive {
    /// Refuse genome spaces wider than this many bits.
    pub max_bits: usize,
    /// Patterns per evaluation batch (one convergence round each; also
    /// the unit the offload flows parallelize trials over).
    pub batch: usize,
}

impl Default for Exhaustive {
    fn default() -> Self {
        Self {
            max_bits: DEFAULT_MAX_BITS,
            batch: 256,
        }
    }
}

impl Strategy for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn search(&self, ctx: &mut SearchCtx<'_>) -> Result<()> {
        let len = ctx.genome_len();
        if len > self.max_bits || len >= usize::BITS as usize - 1 {
            return Err(Error::Config(format!(
                "exhaustive search over a {len}-bit space would run 2^{len} trials \
                 (cap: {} bits); use the ga or anneal strategy instead",
                self.max_bits.min(usize::BITS as usize - 2)
            )));
        }
        let total: usize = 1usize << len;
        let batch = self.batch.max(1);
        let mut best = f64::NEG_INFINITY;
        let mut start = 0usize;
        while start < total {
            let end = (start + batch).min(total);
            // Index 0 is the all-CPU baseline — measured first, like every
            // other strategy.
            let genomes: Vec<Genome> = (start..end).map(|i| Genome::from_index(len, i)).collect();
            let values = ctx.values(&genomes);
            let mut sum = 0.0;
            for &v in &values {
                if v > best {
                    best = v;
                }
                sum += v;
            }
            ctx.record(best, sum / values.len() as f64);
            start = end;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::strategy::run_synthetic;

    #[test]
    fn finds_the_global_optimum_by_enumeration() {
        // A deceptive landscape a hill-climber cannot solve: only one
        // exact pattern scores.
        let target = Genome::from_index(6, 0b101101);
        let t = target.clone();
        let r = run_synthetic(&Exhaustive::default(), 6, 1, move |g| {
            if *g == t {
                50.0
            } else {
                0.0
            }
        })
        .unwrap();
        assert_eq!(r.best, target);
        assert_eq!(r.measured, 64, "the whole space is measured exactly once");
        assert_eq!(r.cache_hits, 0, "no pattern is proposed twice");
    }

    #[test]
    fn batches_bound_round_count_and_history_is_monotone() {
        let strat = Exhaustive {
            batch: 16,
            ..Default::default()
        };
        let r = run_synthetic(&strat, 8, 3, |g| g.ones() as f64).unwrap();
        assert_eq!(r.measured, 256);
        assert_eq!(r.history.len(), 256 / 16);
        for w in r.history.windows(2) {
            assert!(w[1].best >= w[0].best);
        }
        assert_eq!(r.best.ones(), 8);
    }

    #[test]
    fn wide_spaces_are_refused_with_a_clean_error() {
        let strat = Exhaustive {
            max_bits: 8,
            ..Default::default()
        };
        let err = run_synthetic(&strat, 9, 1, |_| 0.0).unwrap_err();
        assert!(err.to_string().contains("2^9"), "{err}");
    }

    #[test]
    fn deterministic_and_seed_independent() {
        // Enumeration ignores the seed: identical archives either way.
        let a = run_synthetic(&Exhaustive::default(), 5, 1, |g| g.ones() as f64).unwrap();
        let b = run_synthetic(&Exhaustive::default(), 5, 999, |g| g.ones() as f64).unwrap();
        assert_eq!(a.best, b.best);
        assert_eq!(a.measured, b.measured);
        assert_eq!(a.best_value, b.best_value);
    }
}
