//! Objective vectors and their operator scalarizations.
//!
//! The paper's evaluation value ("goodness of fit"):
//!
//! > `(Processing time)^(-1/2) * (Power consumption)^(-1/2)` is set to
//! > increase goodness of fit value for short processing time and low
//! > power consumption. (§3.1, §3.3, §4.1b)
//!
//! §3.3 notes the formula "must be set differently per business operator"
//! (power is only part of operation cost) — so the search layer treats a
//! measured trial as a **vector** of [`Objectives`] with Pareto dominance
//! ([`super::pareto`]), and a [`FitnessSpec`] is one operator's
//! *scalarization*: it guides strategies that need a scalar (the GA's
//! selection pressure) and picks the knee point from the non-dominated
//! front after the search (scalarization-last). `time_only()` gives the
//! previous papers' time-only fitness used as the ablation baseline in
//! the Fig. 2 bench.

use super::genome::Genome;

/// The objective vector of one measured trial. The three Pareto axes
/// (time, energy, peak draw) are all minimized; `measured_peak_w`,
/// `mean_w` and `timed_out` ride along so any scalarization can reproduce
/// the paper's evaluation value bit-for-bit from the vector alone (under
/// sampled meters, mean power is not exactly `energy / time`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    /// Wall processing time, seconds.
    pub time_s: f64,
    /// Whole-server energy, Watt·seconds.
    pub energy_ws: f64,
    /// Exact peak whole-server draw of the attributed profile, Watts —
    /// the Pareto axis. Noise- and sampling-free so dominance does not
    /// wobble with the sensor: the all-CPU baseline (the lowest-draw run
    /// an operator can buy) is never knocked off the front by a lucky
    /// sample of a busier pattern.
    pub peak_w: f64,
    /// Sensor-measured peak draw, Watts — what the §3.3 operator Watt cap
    /// is enforced on (the operator only sees the sensor).
    pub measured_peak_w: f64,
    /// Mean whole-server power, Watts (scalarization input).
    pub mean_w: f64,
    /// Trial timed out or failed (scalarizations substitute 1,000 s).
    pub timed_out: bool,
}

impl Objectives {
    /// Synthetic objectives whose paper-scalarization is `sqrt(1 + score)`
    /// — strictly monotone in `score`, so rankings carry over. For engine
    /// tests and throughput benches that search a synthetic landscape
    /// instead of running real verification trials
    /// ([`super::run_synthetic`]).
    pub fn synthetic(score: f64) -> Self {
        let p = 1.0 / (1.0 + score.max(0.0));
        Self {
            time_s: 1.0,
            energy_ws: p,
            peak_w: p,
            measured_peak_w: p,
            mean_w: p,
            timed_out: false,
        }
    }

    /// Are all Pareto axes finite? (Non-finite points are kept out of
    /// fronts.)
    pub fn is_finite(&self) -> bool {
        self.time_s.is_finite() && self.energy_ws.is_finite() && self.peak_w.is_finite()
    }
}

/// A measured genome with its objective vector — one search-log entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Scored {
    /// The pattern.
    pub genome: Genome,
    /// Its measured objectives.
    pub objectives: Objectives,
}

/// Evaluation-value specification (one operator's scalarization).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FitnessSpec {
    /// Exponent `a` in `t^(-a)`.
    pub time_exp: f64,
    /// Exponent `b` in `p^(-b)`.
    pub power_exp: f64,
    /// Verification-trial timeout, seconds (paper: 3 minutes).
    pub timeout_s: f64,
    /// Time substituted when a trial times out (paper: 1,000 s).
    pub timeout_time_s: f64,
    /// Optional operator Watt cap (§3.3: the evaluation is "set per
    /// business operator"): a pattern whose *measured peak* draw exceeds
    /// this budget is rejected — it scores like a timed-out trial and the
    /// offload flows never select it over a cap-respecting pattern.
    pub watt_cap: Option<f64>,
}

impl Default for FitnessSpec {
    fn default() -> Self {
        Self::paper()
    }
}

impl FitnessSpec {
    /// The paper's setting: `t^(-1/2) · p^(-1/2)`, 3-minute timeout → 1000 s.
    pub fn paper() -> Self {
        Self {
            time_exp: 0.5,
            power_exp: 0.5,
            timeout_s: 180.0,
            timeout_time_s: 1000.0,
            watt_cap: None,
        }
    }

    /// Time-only fitness (the previous papers' objective; ablation arm).
    pub fn time_only() -> Self {
        Self {
            power_exp: 0.0,
            ..Self::paper()
        }
    }

    /// Power-weighted variant for operators whose electricity share of
    /// operation cost is high (§3.3 discussion).
    pub fn power_heavy() -> Self {
        Self {
            time_exp: 0.25,
            power_exp: 0.75,
            ..Self::paper()
        }
    }

    /// Same spec with an operator Watt cap.
    pub fn with_watt_cap(self, cap_w: f64) -> Self {
        Self {
            watt_cap: Some(cap_w),
            ..self
        }
    }

    /// Does a measured peak draw violate the operator's Watt cap?
    pub fn exceeds_cap(&self, peak_w: f64) -> bool {
        self.watt_cap.is_some_and(|cap| peak_w > cap)
    }

    /// Evaluation value of a measurement. Larger is better. `time_s` is
    /// replaced by [`FitnessSpec::timeout_time_s`] when `timed_out`.
    pub fn value(&self, time_s: f64, mean_power_w: f64, timed_out: bool) -> f64 {
        let t = if timed_out {
            self.timeout_time_s
        } else {
            time_s.max(1e-9)
        };
        let p = mean_power_w.max(1e-9);
        t.powf(-self.time_exp) * p.powf(-self.power_exp)
    }

    /// Scalarize an objective vector: like [`FitnessSpec::value`], but a
    /// measured peak above the Watt cap is scored like a timeout — the
    /// §3.3 operator constraint the offload flows search under.
    pub fn scalarize(&self, o: &Objectives) -> f64 {
        let capped = self.exceeds_cap(o.measured_peak_w);
        self.value(o.time_s, o.mean_w, o.timed_out || capped)
    }

    /// Evaluation value of a full measurement record (the scalarization of
    /// its [`Objectives`]).
    pub fn value_of(&self, m: &crate::verifier::Measurement) -> f64 {
        self.scalarize(&m.objectives())
    }

    /// Same spec, capped at the per-job Watt sub-budget derived from a
    /// fleet-wide cap (see [`watt_sub_budget`]) — the tighter of the
    /// fleet headroom and any operator cap already set. With no fleet cap
    /// the spec is returned unchanged.
    pub fn with_fleet_headroom(self, fleet_cap_w: Option<f64>, committed_w: f64) -> Self {
        match watt_sub_budget(fleet_cap_w, committed_w) {
            Some(sub) => {
                let cap = match self.watt_cap {
                    Some(op) => op.min(sub),
                    None => sub,
                };
                self.with_watt_cap(cap)
            }
            None => self,
        }
    }
}

/// Derive one job's operator Watt cap from a fleet-wide cap: the headroom
/// the rest of the fleet leaves it. `committed_w` is the draw already
/// spoken for *excluding* the job itself — the other nodes' idle floors
/// plus the other running jobs' dynamic means — so the job's whole-server
/// measured peak (which includes its own chassis idle) can be compared
/// against the sub-budget directly. A fully-committed fleet yields a 0 W
/// sub-budget: every offload candidate violates it, so the flows fall
/// back to the all-CPU pattern (the unconditional degenerate choice —
/// whether it may *run* is the admission controller's call, not the
/// search's).
pub fn watt_sub_budget(fleet_cap_w: Option<f64>, committed_w: f64) -> Option<f64> {
    fleet_cap_w.map(|cap| (cap - committed_w).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_values_for_fig5() {
        // CPU-only: 14 s @ 121 W ; FPGA: 2 s @ 111 W — the offloaded
        // pattern must score higher.
        let f = FitnessSpec::paper();
        let cpu = f.value(14.0, 121.0, false);
        let fpga = f.value(2.0, 111.0, false);
        assert!(fpga > cpu);
        // Exact values: (14*121)^-0.5 and (2*111)^-0.5.
        assert!((cpu - (14.0f64 * 121.0).powf(-0.5)).abs() < 1e-12);
        assert!((fpga - (2.0f64 * 111.0).powf(-0.5)).abs() < 1e-12);
    }

    #[test]
    fn shorter_time_and_lower_power_both_help() {
        let f = FitnessSpec::paper();
        let base = f.value(10.0, 120.0, false);
        assert!(f.value(5.0, 120.0, false) > base);
        assert!(f.value(10.0, 60.0, false) > base);
    }

    #[test]
    fn timeout_substitutes_1000s() {
        let f = FitnessSpec::paper();
        let timed = f.value(150.0, 120.0, true);
        assert!((timed - (1000.0f64 * 120.0).powf(-0.5)).abs() < 1e-12);
        // A timed-out 150 s trial scores worse than a clean 900 s one.
        assert!(timed < f.value(900.0, 120.0, false));
    }

    #[test]
    fn time_only_ignores_power() {
        let f = FitnessSpec::time_only();
        assert_eq!(f.value(4.0, 50.0, false), f.value(4.0, 500.0, false));
    }

    #[test]
    fn scalarize_matches_value_on_clean_objectives() {
        let f = FitnessSpec::paper();
        let o = Objectives {
            time_s: 2.0,
            energy_ws: 222.0,
            peak_w: 129.0,
            measured_peak_w: 121.0,
            mean_w: 111.0,
            timed_out: false,
        };
        assert_eq!(f.scalarize(&o), f.value(2.0, 111.0, false));
        let timed = Objectives { timed_out: true, ..o };
        assert_eq!(f.scalarize(&timed), f.value(2.0, 111.0, true));
        // The cap reads the *measured* peak, not the exact profile peak.
        let capped = f.with_watt_cap(125.0);
        assert_eq!(capped.scalarize(&o), f.value(2.0, 111.0, false));
        let hot = Objectives { measured_peak_w: 130.0, ..o };
        assert_eq!(capped.scalarize(&hot), f.value(2.0, 111.0, true));
    }

    #[test]
    fn synthetic_objectives_rank_by_score() {
        let f = FitnessSpec::paper();
        let lo = f.scalarize(&Objectives::synthetic(1.0));
        let hi = f.scalarize(&Objectives::synthetic(9.0));
        assert!(hi > lo);
        assert_eq!(
            f.scalarize(&Objectives::synthetic(4.0)),
            f.scalarize(&Objectives::synthetic(4.0))
        );
        assert!(Objectives::synthetic(3.0).is_finite());
        assert!(!Objectives {
            time_s: f64::NAN,
            ..Objectives::synthetic(1.0)
        }
        .is_finite());
    }

    #[test]
    fn watt_cap_scores_violators_like_timeouts() {
        use crate::canalyze::LoopId;
        use crate::power::{EnergyReport, PowerTrace};
        use crate::verifier::{Measurement, PhaseKind, TrialBreakdown};
        let meas = |peak_w: f64| Measurement {
            app: "t.c".into(),
            device: crate::devices::DeviceKind::Gpu,
            pattern: vec![true],
            regions: vec![LoopId(0)],
            time_s: 2.0,
            mean_w: 150.0,
            energy_ws: 300.0,
            trace: PowerTrace::default(),
            report: EnergyReport::legacy(2.0, 300.0, 150.0, peak_w),
            timed_out: false,
            failure: None,
            breakdown: TrialBreakdown::default(),
            phase: PhaseKind::Verification,
        };
        let f = FitnessSpec::paper().with_watt_cap(200.0);
        assert!(f.exceeds_cap(230.0) && !f.exceeds_cap(200.0));
        let under = f.value_of(&meas(190.0));
        let over = f.value_of(&meas(230.0));
        assert!((under - f.value(2.0, 150.0, false)).abs() < 1e-15);
        assert!((over - f.value(2.0, 150.0, true)).abs() < 1e-15);
        assert!(under > over);
        // Without a cap, peak draw does not matter.
        let unc = FitnessSpec::paper();
        assert_eq!(unc.value_of(&meas(230.0)), unc.value_of(&meas(190.0)));
    }

    #[test]
    fn sub_budget_is_fleet_headroom() {
        assert_eq!(watt_sub_budget(None, 210.0), None);
        assert_eq!(watt_sub_budget(Some(330.0), 210.0), Some(120.0));
        // Over-committed fleets clamp to a 0 W budget (nothing runnable).
        assert_eq!(watt_sub_budget(Some(200.0), 210.0), Some(0.0));
        let f = FitnessSpec::paper().with_fleet_headroom(Some(220.0), 105.0);
        assert_eq!(f.watt_cap, Some(115.0));
        assert!(f.exceeds_cap(121.0) && !f.exceeds_cap(110.0));
        let unchanged = FitnessSpec::paper().with_fleet_headroom(None, 105.0);
        assert_eq!(unchanged.watt_cap, None);
        // An operator cap tighter than the fleet headroom survives.
        let op = FitnessSpec::paper().with_watt_cap(110.0);
        assert_eq!(op.with_fleet_headroom(Some(400.0), 105.0).watt_cap, Some(110.0));
        assert_eq!(op.with_fleet_headroom(Some(200.0), 105.0).watt_cap, Some(95.0));
    }

    #[test]
    fn power_heavy_prefers_low_power_trade() {
        // 10% slower but 30% lower power: power-heavy must prefer it,
        // while time-only must not.
        let ph = FitnessSpec::power_heavy();
        let to = FitnessSpec::time_only();
        assert!(ph.value(11.0, 84.0, false) > ph.value(10.0, 120.0, false));
        assert!(to.value(11.0, 84.0, false) < to.value(10.0, 120.0, false));
    }
}
