/*
 * 2D Jacobi 5-point stencil with ping-pong buffers — the IoT
 * image-processing stand-in workload. The time-stepping loop calls the
 * sweep/copy helpers, so it stays on the CPU (user-function calls), while
 * the row/column sweeps inside jacobi() are clean offload candidates.
 */

void init(float *a, int n) {
  for (int i = 0; i < n; i++) {
    a[i] = sinf(0.1f * (float) i) + 1.5f;
  }
}

void jacobi(float *a, float *b, int w, int h) {
  for (int i = 1; i < h - 1; i++) {
    for (int j = 1; j < w - 1; j++) {
      b[i * w + j] = 0.2f * (a[i * w + j] + a[i * w + j - 1] + a[i * w + j + 1]
                             + a[(i - 1) * w + j] + a[(i + 1) * w + j]);
    }
  }
}

void copyback(float *dst, float *src, int n) {
  for (int i = 0; i < n; i++) {
    dst[i] = src[i];
  }
}

int main() {
  float a[256];
  float b[256];
  init(a, 256);
  init(b, 256);

  /* Time stepping: each sweep depends on the previous one. */
  for (int t = 0; t < 4; t++) {
    jacobi(a, b, 16, 16);
    copyback(a, b, 256);
  }

  float total = 0.0f;
  for (int i = 0; i < 256; i++) {
    total += a[i];
  }
  float peak = 0.0f;
  for (int i = 0; i < 256; i++) {
    if (a[i] > peak) {
      peak = a[i];
    }
  }
  printf("%f %f\n", total, peak);
  return 0;
}
