/*
 * Vector addition — the transfer-dominated quickstart workload: almost no
 * arithmetic per element, so PCIe payloads dominate any offload and the
 * measurement-driven search usually concludes the CPU should keep it.
 */

void vecadd(float *c, float *a, float *b, int n) {
  for (int i = 0; i < n; i++) {
    c[i] = a[i] + b[i];
  }
}

int main() {
  float a[4096];
  float b[4096];
  float c[4096];

  for (int i = 0; i < 4096; i++) {
    a[i] = 0.001f * (float) i;
  }
  for (int i = 0; i < 4096; i++) {
    b[i] = 2.0f - 0.0005f * (float) i;
  }

  vecadd(c, a, b, 4096);

  float s = 0.0f;
  for (int i = 0; i < 4096; i++) {
    s += c[i];
  }
  printf("%f\n", s);
  return 0;
}
