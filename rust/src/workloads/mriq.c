/*
 * Parboil MRI-Q (C subset) — the paper's evaluated application (section 4.1).
 *
 * Non-uniform-FFT Q-matrix computation: for every voxel, accumulate the
 * cosine/sine contributions of every k-space sample. The computeQ nest
 * dominates the dynamic FLOP count (>97%), exactly like the original
 * benchmark, so offloading it is the whole game.
 *
 * Written so the dependence analyzer finds the paper's 16 processable
 * loop statements out of 19 total: the peak-scan (scalar overwrite), the
 * mip-level while loop, and the printf loop stay on the CPU.
 *
 * Sample size 128 k-samples x 512 voxels; the verification environment
 * scales to the testbed's 64^3 x 2048 problem via the measured-baseline
 * calibration (see DESIGN.md section 6).
 */

void genTraj(float *kx, float *ky, float *kz, float *phiR, float *phiI, int numK) {
  for (int k = 0; k < numK; k++) {
    float t = (float) k / (float) numK;
    kx[k] = 0.5f * cosf(6.2831855f * 3.0f * t);
    ky[k] = 0.5f * sinf(6.2831855f * 3.0f * t);
    kz[k] = t - 0.5f;
    float w = 0.54f - 0.46f * cosf(6.2831855f * t);
    phiR[k] = (1.0f - 0.5f * t) * w;
    phiI[k] = 0.25f * sinf(6.2831855f * t) * w;
  }
}

void genVox(float *x, float *y, float *z, int numX) {
  for (int i = 0; i < numX; i++) {
    x[i] = ((float) (i % 8) / 8.0f - 0.5f) * 0.9f;
    y[i] = ((float) ((i / 8) % 8) / 8.0f - 0.5f) * 0.9f;
    z[i] = ((float) (i / 64) / 8.0f - 0.5f) * 0.9f;
  }
}

void computePhiMag(float *phiR, float *phiI, float *phiMag, int numK) {
  for (int k = 0; k < numK; k++) {
    float re = phiR[k];
    float im = phiI[k];
    phiMag[k] = sqrtf(re * re + im * im);
  }
}

void computeQ(int numK, int numX, float *kx, float *ky, float *kz,
              float *x, float *y, float *z, float *phiMag,
              float *qr, float *qi) {
  for (int v = 0; v < numX; v++) {
    float xs = x[v];
    float ys = y[v];
    float zs = z[v];
    float ar = 0.0f;
    float ai = 0.0f;
    for (int k = 0; k < numK; k++) {
      float e = 6.2831855f * (kx[k] * xs + ky[k] * ys + kz[k] * zs);
      ar += phiMag[k] * cosf(e);
      ai += phiMag[k] * sinf(e);
    }
    qr[v] = ar;
    qi[v] = ai;
  }
}

int main() {
  float kx[128];
  float ky[128];
  float kz[128];
  float phiR[128];
  float phiI[128];
  float phiMag[128];
  float x[512];
  float y[512];
  float z[512];
  float qr[512];
  float qi[512];
  float qmag[512];

  genTraj(kx, ky, kz, phiR, phiI, 128);
  genVox(x, y, z, 512);

  /* Clear the accumulators (Parboil: createDataStructsCPU). */
  for (int i = 0; i < 512; i++) {
    qr[i] = 0.0f;
  }
  for (int j = 0; j < 512; j++) {
    qi[j] = 0.0f;
  }

  /* Apodization window on the phase samples. */
  for (int k = 0; k < 128; k++) {
    float w = 0.54f - 0.46f * cosf(6.2831855f * (float) k / 128.0f);
    phiR[k] *= w;
    phiI[k] *= w;
  }

  computePhiMag(phiR, phiI, phiMag, 128);

  /* Shrink the voxel lattice toward the field-of-view center. */
  for (int i = 0; i < 512; i++) {
    x[i] *= 0.98f;
    y[i] *= 0.98f;
    z[i] *= 0.98f;
  }

  computeQ(128, 512, kx, ky, kz, x, y, z, phiMag, qr, qi);

  /* Checksums over the Q matrix. */
  float sumR = 0.0f;
  for (int i = 0; i < 512; i++) {
    sumR += qr[i];
  }
  float sumI = 0.0f;
  for (int i = 0; i < 512; i++) {
    sumI += qi[i];
  }
  float energy = 0.0f;
  for (int i = 0; i < 512; i++) {
    energy += qr[i] * qr[i] + qi[i] * qi[i];
  }

  /* Peak magnitude: the scalar overwrite keeps this one on the CPU. */
  float peak = 0.0f;
  for (int i = 0; i < 512; i++) {
    float m = fabsf(qr[i]);
    if (m > peak) {
      peak = m;
    }
  }

  /* Magnitude image. */
  for (int i = 0; i < 512; i++) {
    qmag[i] = sqrtf(qr[i] * qr[i] + qi[i] * qi[i]);
  }

  /* Normalize by the (shifted) peak. */
  for (int i = 0; i < 512; i++) {
    qmag[i] /= peak + 1.0f;
  }

  /* Second moment of the normalized image. */
  float m2 = 0.0f;
  for (int i = 0; i < 512; i++) {
    m2 += qmag[i] * qmag[i];
  }

  /* Remove the mean level. */
  for (int i = 0; i < 512; i++) {
    qmag[i] -= m2 / 512.0f;
  }

  /* Mip-level count: data-driven trip count, never offloaded. */
  int levels = 0;
  int span = 512;
  while (span > 1) {
    span /= 2;
    levels += 1;
  }

  /* Print the first samples (I/O stays on the CPU). */
  for (int i = 0; i < 2; i++) {
    printf("%f %f\n", qr[i], qi[i]);
  }

  printf("%f %f %f %f\n", sumR, sumI, energy, peak);
  return 0;
}
