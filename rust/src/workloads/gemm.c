/*
 * Dense matrix multiply, written as the naive triple loop the
 * function-block detector must recognize: c = a * b over n x n matrices
 * stored row-major in 1-D arrays. Loop-only offloading can ship the
 * outer nest to a device as-is; block offloading replaces the whole
 * gemm() nest with a tuned library (cuBLAS) or a systolic IP core.
 */

void gemm(float *c, float *a, float *b, int n) {
  for (int i = 0; i < n; i++) {
    for (int j = 0; j < n; j++) {
      float s = 0.0f;
      for (int k = 0; k < n; k++) {
        s += a[i * n + k] * b[k * n + j];
      }
      c[i * n + j] = s;
    }
  }
}

int main() {
  float a[1600];
  float b[1600];
  float c[1600];

  for (int i = 0; i < 1600; i++) {
    a[i] = 0.001f * (float) (i % 97);
  }
  for (int i = 0; i < 1600; i++) {
    b[i] = 0.5f - 0.002f * (float) (i % 53);
  }

  gemm(c, a, b, 40);

  float trace = 0.0f;
  for (int i = 0; i < 40; i++) {
    trace += c[i * 40 + i];
  }
  float total = 0.0f;
  for (int i = 0; i < 1600; i++) {
    total += c[i];
  }
  printf("%f %f\n", trace, total);
  return 0;
}
