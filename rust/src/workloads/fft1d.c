/*
 * 1-D Fourier transform, written as the naive O(n^2) DFT double loop the
 * function-block detector must recognize: the twiddle angle is computed
 * from BOTH induction variables (k * t), which is what separates a true
 * DFT from MRI-Q's non-uniform variant (whose phase comes from array
 * elements). Block offloading replaces the whole nest with an
 * O(n log n) library FFT (cuFFT / FFTW / streaming IP core).
 */

void fft1d(float *xr, float *xi, float *inr, float *ini, int n) {
  for (int k = 0; k < n; k++) {
    float sr = 0.0f;
    float si = 0.0f;
    for (int t = 0; t < n; t++) {
      float ang = 6.2831853f * (float) k * (float) t / (float) n;
      float c = cosf(ang);
      float s = sinf(ang);
      sr += inr[t] * c + ini[t] * s;
      si += ini[t] * c - inr[t] * s;
    }
    xr[k] = sr;
    xi[k] = si;
  }
}

int main() {
  float inr[96];
  float ini[96];
  float xr[96];
  float xi[96];

  for (int i = 0; i < 96; i++) {
    inr[i] = sinf(0.21f * (float) i) + 0.5f * sinf(0.57f * (float) i);
  }
  for (int i = 0; i < 96; i++) {
    ini[i] = 0.0f;
  }

  fft1d(xr, xi, inr, ini, 96);

  float energy = 0.0f;
  for (int k = 0; k < 96; k++) {
    energy += xr[k] * xr[k] + xi[k] * xi[k];
  }
  printf("%f %f %f\n", xr[0], xi[1], energy);
  return 0;
}
