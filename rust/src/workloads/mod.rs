//! Bundled C-subset workloads used by the examples, tests and benches.
//!
//! * [`MRIQ_C`] — the paper's evaluated application (Parboil MRI-Q, §4.1),
//!   written so the dependence analyzer finds exactly the paper's
//!   **16 processable loop statements**.
//! * [`STENCIL_C`] — 2D Jacobi stencil (IoT image-processing stand-in).
//! * [`HISTO_C`] — histogram with non-parallelizable binning/scan loops.
//! * [`VECADD_C`] — transfer-dominated quickstart workload.
//! * [`GEMM_C`] — naive triple-loop matrix multiply (the
//!   [`crate::funcblock`] matmul detection target).
//! * [`FFT1D_C`] — naive O(n²) DFT double loop (the funcblock FFT
//!   detection target).

/// Parboil MRI-Q (C subset), 16 processable loops — the paper's §4 subject.
pub const MRIQ_C: &str = include_str!("mriq.c");

/// 2D Jacobi 5-point stencil with ping-pong buffers.
pub const STENCIL_C: &str = include_str!("stencil.c");

/// Histogram with indirect stores and a prefix scan.
pub const HISTO_C: &str = include_str!("histo.c");

/// Vector addition (quickstart).
pub const VECADD_C: &str = include_str!("vecadd.c");

/// Naive triple-loop dense matrix multiply (function-block target).
pub const GEMM_C: &str = include_str!("gemm.c");

/// Naive O(n²) DFT double loop (function-block target).
pub const FFT1D_C: &str = include_str!("fft1d.c");

/// Resolve a user-supplied name to the canonical `(name, source)` pair.
/// Tolerant: matching is case-insensitive, surrounding whitespace is
/// ignored and a trailing `.c` is stripped, so `MRIQ`, `mriq.c` and
/// `Mriq.C` all resolve to `("mriq", MRIQ_C)`. This is the single home
/// of the normalization rule — the CLI derives its display name from the
/// canonical name returned here.
pub fn resolve(name: &str) -> Option<(&'static str, &'static str)> {
    let lower = name.trim().to_ascii_lowercase();
    let base = lower.strip_suffix(".c").unwrap_or(&lower);
    ALL.iter().find(|(n, _)| *n == base).copied()
}

/// Name → source lookup for the CLI (`enadapt analyze mriq` etc.).
/// See [`resolve`] for the tolerance rules.
pub fn by_name(name: &str) -> Option<&'static str> {
    resolve(name).map(|(_, src)| src)
}

/// The bundled workload names (for CLI error messages).
pub fn names() -> Vec<&'static str> {
    ALL.iter().map(|(n, _)| *n).collect()
}

/// All bundled workloads as `(name, source)` pairs.
pub const ALL: &[(&str, &str)] = &[
    ("mriq", MRIQ_C),
    ("stencil", STENCIL_C),
    ("histo", HISTO_C),
    ("vecadd", VECADD_C),
    ("gemm", GEMM_C),
    ("fft1d", FFT1D_C),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::canalyze::analyze_source;

    #[test]
    fn mriq_has_exactly_16_processable_loops() {
        let an = analyze_source("mriq.c", MRIQ_C).unwrap();
        assert_eq!(
            an.parallelizable_ids().len(),
            16,
            "paper (§4.1b): 16 processable loop statements for MRI-Q; reasons: {:#?}",
            an.loops
                .iter()
                .filter(|l| !l.parallelizable)
                .map(|l| (l.id, l.line, l.not_parallel_reason.clone()))
                .collect::<Vec<_>>()
        );
        assert_eq!(an.n_loops(), 19, "19 loop statements total");
    }

    #[test]
    fn mriq_profile_is_dominated_by_compute_q() {
        let an = analyze_source("mriq.c", MRIQ_C).unwrap();
        let p = an.profile.as_ref().unwrap();
        // The computeQ outer loop nest must dominate dynamic FLOPs (the
        // paper offloads it for the 7x speedup).
        let outer = an
            .loops
            .iter()
            .find(|l| l.func == "computeQ" && l.depth == 0)
            .unwrap();
        let share = p.flop_share(&an.loops, outer.id);
        assert!(share > 0.9, "computeQ share = {share}");
    }

    #[test]
    fn mriq_prints_plausible_output() {
        let an = analyze_source("mriq.c", MRIQ_C).unwrap();
        let out = &an.profile.as_ref().unwrap().printed;
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|v| v.is_finite()));
        // Energy and peak are positive.
        assert!(out[6] > 0.0 && out[7] > 0.0);
    }

    #[test]
    fn all_workloads_analyze_cleanly() {
        for (name, src) in ALL {
            let an = analyze_source(name, src).unwrap();
            assert!(an.n_loops() > 0, "{name} has loops");
            assert!(an.profile.is_some(), "{name} profiles");
            assert!(!an.parallelizable_ids().is_empty(), "{name} has candidates");
        }
    }

    #[test]
    fn histo_binning_is_rejected() {
        let an = analyze_source("histo.c", HISTO_C).unwrap();
        let rejected: Vec<_> = an.loops.iter().filter(|l| !l.parallelizable).collect();
        assert!(!rejected.is_empty());
        let reasons: Vec<_> = rejected
            .iter()
            .filter_map(|l| l.not_parallel_reason.as_deref())
            .collect();
        assert!(
            reasons.iter().any(|r| r.contains("indirect store")),
            "reasons: {reasons:?}"
        );
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("mriq").is_some());
        assert!(by_name("mriq.c").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn by_name_is_tolerant() {
        assert_eq!(by_name("MRIQ"), Some(MRIQ_C));
        assert_eq!(by_name("Mriq.C"), Some(MRIQ_C));
        assert_eq!(by_name("  stencil.c "), Some(STENCIL_C));
        assert_eq!(by_name("VecAdd"), Some(VECADD_C));
        assert!(by_name("mriq.cpp").is_none());
    }

    #[test]
    fn names_lists_all() {
        assert_eq!(
            names(),
            vec!["mriq", "stencil", "histo", "vecadd", "gemm", "fft1d"]
        );
    }

    #[test]
    fn gemm_and_fft1d_have_the_naive_block_idioms() {
        let gemm = analyze_source("gemm.c", GEMM_C).unwrap();
        // Triple loop in gemm() + four main loops.
        assert_eq!(gemm.n_loops(), 7);
        assert!(gemm.loops.iter().any(|l| l.func == "gemm" && l.depth == 2));
        let fft = analyze_source("fft1d.c", FFT1D_C).unwrap();
        assert!(fft.loops.iter().any(|l| l.func == "fft1d" && l.depth == 1));
        // Both profile cleanly and have offload candidates.
        assert!(gemm.profile.is_some() && fft.profile.is_some());
        assert!(!gemm.parallelizable_ids().is_empty());
        assert!(!fft.parallelizable_ids().is_empty());
    }

    #[test]
    fn resolve_returns_canonical_name() {
        assert_eq!(resolve("Mriq.C").map(|(n, _)| n), Some("mriq"));
        assert_eq!(resolve(" HISTO "), Some(("histo", HISTO_C)));
        assert!(resolve("nope").is_none());
    }
}
