//! Bundled C-subset workloads used by the examples, tests and benches.
//!
//! * [`MRIQ_C`] — the paper's evaluated application (Parboil MRI-Q, §4.1),
//!   written so the dependence analyzer finds exactly the paper's
//!   **16 processable loop statements**.
//! * [`STENCIL_C`] — 2D Jacobi stencil (IoT image-processing stand-in).
//! * [`HISTO_C`] — histogram with non-parallelizable binning/scan loops.
//! * [`VECADD_C`] — transfer-dominated quickstart workload.

/// Parboil MRI-Q (C subset), 16 processable loops — the paper's §4 subject.
pub const MRIQ_C: &str = include_str!("mriq.c");

/// 2D Jacobi 5-point stencil with ping-pong buffers.
pub const STENCIL_C: &str = include_str!("stencil.c");

/// Histogram with indirect stores and a prefix scan.
pub const HISTO_C: &str = include_str!("histo.c");

/// Vector addition (quickstart).
pub const VECADD_C: &str = include_str!("vecadd.c");

/// Name → source lookup for the CLI (`enadapt analyze mriq` etc.).
pub fn by_name(name: &str) -> Option<&'static str> {
    match name {
        "mriq" | "mriq.c" => Some(MRIQ_C),
        "stencil" | "stencil.c" => Some(STENCIL_C),
        "histo" | "histo.c" => Some(HISTO_C),
        "vecadd" | "vecadd.c" => Some(VECADD_C),
        _ => None,
    }
}

/// All bundled workloads as `(name, source)` pairs.
pub const ALL: &[(&str, &str)] = &[
    ("mriq", MRIQ_C),
    ("stencil", STENCIL_C),
    ("histo", HISTO_C),
    ("vecadd", VECADD_C),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::canalyze::analyze_source;

    #[test]
    fn mriq_has_exactly_16_processable_loops() {
        let an = analyze_source("mriq.c", MRIQ_C).unwrap();
        assert_eq!(
            an.parallelizable_ids().len(),
            16,
            "paper (§4.1b): 16 processable loop statements for MRI-Q; reasons: {:#?}",
            an.loops
                .iter()
                .filter(|l| !l.parallelizable)
                .map(|l| (l.id, l.line, l.not_parallel_reason.clone()))
                .collect::<Vec<_>>()
        );
        assert_eq!(an.n_loops(), 19, "19 loop statements total");
    }

    #[test]
    fn mriq_profile_is_dominated_by_compute_q() {
        let an = analyze_source("mriq.c", MRIQ_C).unwrap();
        let p = an.profile.as_ref().unwrap();
        // The computeQ outer loop nest must dominate dynamic FLOPs (the
        // paper offloads it for the 7x speedup).
        let outer = an
            .loops
            .iter()
            .find(|l| l.func == "computeQ" && l.depth == 0)
            .unwrap();
        let share = p.flop_share(&an.loops, outer.id);
        assert!(share > 0.9, "computeQ share = {share}");
    }

    #[test]
    fn mriq_prints_plausible_output() {
        let an = analyze_source("mriq.c", MRIQ_C).unwrap();
        let out = &an.profile.as_ref().unwrap().printed;
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|v| v.is_finite()));
        // Energy and peak are positive.
        assert!(out[6] > 0.0 && out[7] > 0.0);
    }

    #[test]
    fn all_workloads_analyze_cleanly() {
        for (name, src) in ALL {
            let an = analyze_source(name, src).unwrap();
            assert!(an.n_loops() > 0, "{name} has loops");
            assert!(an.profile.is_some(), "{name} profiles");
            assert!(!an.parallelizable_ids().is_empty(), "{name} has candidates");
        }
    }

    #[test]
    fn histo_binning_is_rejected() {
        let an = analyze_source("histo.c", HISTO_C).unwrap();
        let rejected: Vec<_> = an.loops.iter().filter(|l| !l.parallelizable).collect();
        assert!(!rejected.is_empty());
        let reasons: Vec<_> = rejected
            .iter()
            .filter_map(|l| l.not_parallel_reason.as_deref())
            .collect();
        assert!(
            reasons.iter().any(|r| r.contains("indirect store")),
            "reasons: {reasons:?}"
        );
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("mriq").is_some());
        assert!(by_name("mriq.c").is_some());
        assert!(by_name("nope").is_none());
    }
}
