/*
 * Histogram with indirect stores and a prefix scan — the workload whose
 * hot loops the dependence analysis must REJECT: the binning loop writes
 * h[bin[i]] (indirect store, possible write-write collisions) and the
 * scan carries a running sum across iterations. Data generation, bin
 * indexing and the zeroing loop remain offloadable.
 */

void genData(float *data, int n) {
  for (int i = 0; i < n; i++) {
    data[i] = 0.5f + 0.5f * sinf(0.37f * (float) i);
  }
}

void binIndex(int *bin, float *data, int n, int nb) {
  for (int i = 0; i < n; i++) {
    int b = (int) (data[i] * (float) nb);
    if (b > nb - 1) {
      b = nb - 1;
    }
    bin[i] = b;
  }
}

void histogram(float *h, int *bin, int n) {
  for (int i = 0; i < n; i++) {
    h[bin[i]] += 1.0f;
  }
}

void prefixScan(float *cum, float *h, int nb) {
  float run = 0.0f;
  for (int j = 0; j < nb; j++) {
    run += h[j];
    cum[j] = run;
  }
}

int main() {
  float data[1024];
  int bin[1024];
  float h[32];
  float cum[32];

  genData(data, 1024);
  binIndex(bin, data, 1024, 32);
  for (int j = 0; j < 32; j++) {
    h[j] = 0.0f;
  }
  histogram(h, bin, 1024);
  prefixScan(cum, h, 32);

  float total = cum[31];
  float maxBin = 0.0f;
  for (int j = 0; j < 32; j++) {
    if (h[j] > maxBin) {
      maxBin = h[j];
    }
  }
  printf("%f %f\n", total, maxBin);
  return 0;
}
