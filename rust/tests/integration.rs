//! Cross-module integration tests: every bundled workload through every
//! destination, report/JSON integrity, codegen consistency with the
//! chosen pattern, and the runtime bridge (when artifacts are built).

use enadapt::canalyze::analyze_source;
use enadapt::coordinator::{report, run_job, BaselineSource, Destination, GeneratedCode, JobConfig};
use enadapt::devices::DeviceKind;
use enadapt::offload::GpuFlowConfig;
use enadapt::search::GaConfig;
use enadapt::util::json;
use enadapt::workloads;

fn quick_cfg(dest: Destination, baseline_s: f64) -> JobConfig {
    JobConfig {
        destination: dest,
        baseline: BaselineSource::Fixed(baseline_s),
        ga_flow: GpuFlowConfig {
            ga: GaConfig {
                population: 8,
                generations: 6,
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn every_workload_completes_a_gpu_job() {
    for (name, src) in workloads::ALL {
        let cfg = quick_cfg(Destination::Device(DeviceKind::Gpu), 5.0);
        let job = run_job(name, src, &cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(job.steps.records.len(), 7, "{name}");
        assert!(job.best.value > 0.0, "{name}");
        // Rendering must never panic and must mention the workload.
        let text = report::render_job(&job);
        assert!(text.contains(*name), "{name}");
    }
}

#[test]
fn every_workload_completes_an_fpga_job() {
    for (name, src) in workloads::ALL {
        let cfg = quick_cfg(Destination::Device(DeviceKind::Fpga), 5.0);
        let job = run_job(name, src, &cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(job.production.time_s > 0.0, "{name}");
    }
}

#[test]
fn mixed_job_on_mriq_chooses_low_power_destination() {
    let cfg = quick_cfg(Destination::Mixed, 14.0);
    let job = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
    // With default (satisfiable) requirements the search may stop early at
    // the many-core; the chosen destination must improve on the baseline.
    assert!(job.production.energy_ws < job.baseline.energy_ws);
    assert!(job.production.time_s < job.baseline.time_s);
}

#[test]
fn generated_code_matches_chosen_pattern() {
    let cfg = quick_cfg(Destination::Device(DeviceKind::Gpu), 14.0);
    let job = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
    let regions = job.app.regions(job.best.pattern.bits());
    match &job.generated {
        GeneratedCode::OpenAcc(code) => {
            assert_eq!(
                code.matches("#pragma acc parallel loop").count(),
                regions.len(),
                "one pragma per region"
            );
        }
        GeneratedCode::Unchanged => assert!(regions.is_empty()),
        other => panic!("gpu job must emit OpenACC, got {}", other.kind()),
    }
}

#[test]
fn fpga_job_kernel_count_matches_regions() {
    let cfg = quick_cfg(Destination::Device(DeviceKind::Fpga), 14.0);
    let job = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
    let regions = job.app.regions(job.best.pattern.bits());
    if let GeneratedCode::OpenCl(b) = &job.generated {
        assert_eq!(b.kernel_names.len(), regions.len());
        assert_eq!(
            b.kernel_source.matches("__kernel void").count(),
            regions.len()
        );
    } else if !regions.is_empty() {
        panic!("fpga job with regions must emit OpenCL");
    }
}

#[test]
fn job_json_roundtrips_and_has_required_fields() {
    let cfg = quick_cfg(Destination::Device(DeviceKind::Fpga), 14.0);
    let job = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
    let j = report::job_json(&job);
    let text = j.to_string_pretty();
    let back = json::parse(&text).unwrap();
    for key in [
        "source",
        "device",
        "pattern",
        "value",
        "baseline",
        "production",
        "trials",
        "steps",
    ] {
        assert!(back.get(key).is_some(), "missing {key}");
    }
    assert_eq!(back.get("steps").unwrap().as_arr().unwrap().len(), 7);
}

#[test]
fn deterministic_jobs_for_same_seed() {
    let cfg = quick_cfg(Destination::Device(DeviceKind::Fpga), 14.0);
    let a = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
    let b = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
    assert_eq!(a.best.pattern.genome, b.best.pattern.genome);
    assert_eq!(a.production.energy_ws, b.production.energy_ws);
}

#[test]
fn different_seeds_may_differ_but_stay_valid() {
    for seed in [1, 2, 3] {
        let mut cfg = quick_cfg(Destination::Device(DeviceKind::Gpu), 14.0);
        cfg.seed = seed;
        cfg.ga_flow.seed = seed;
        let job = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
        assert!(job.best.value >= 0.0);
        assert_eq!(job.best.pattern.genome.len(), 16);
    }
}

#[test]
fn runtime_bridge_calibrates_baseline_when_artifacts_exist() {
    let arts = enadapt::runtime::load_artifacts(&enadapt::runtime::default_dir());
    match arts {
        Ok(a) if a.complete() => {
            let cfg = JobConfig {
                baseline: BaselineSource::MeasuredHlo {
                    artifact: "mriq_cpu_small".into(),
                    full_k: 2048,
                    full_x: 262_144,
                },
                ..quick_cfg(Destination::Device(DeviceKind::Fpga), 0.0)
            };
            let job = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
            // Measured baseline is machine-dependent but must be seconds-
            // scale and the offload must still win.
            assert!(job.baseline.time_s > 0.5, "baseline {}", job.baseline.time_s);
            assert!(job.production.time_s < job.baseline.time_s);
        }
        _ => eprintln!("skipping: artifacts not built"),
    }
}

#[test]
fn analyze_then_model_pipeline_is_consistent() {
    for (name, src) in workloads::ALL {
        let an = analyze_source(name, src).unwrap();
        let cfg = enadapt::verifier::VerifEnvConfig::r740_pac();
        let app = enadapt::verifier::AppModel::from_analysis(&an, &cfg.cpu, 3.0).unwrap();
        assert_eq!(app.genome_len(), an.parallelizable_ids().len(), "{name}");
        // Offloading everything never leaves negative host time.
        let all = vec![true; app.genome_len()];
        let regions = app.regions(&all);
        assert!(app.host_remainder_s(&regions) >= 0.0, "{name}");
    }
}
