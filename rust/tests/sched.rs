//! Integration tests for the power-budget fleet scheduler
//! (`coordinator::sched`): bit-identical ledgers per seed, Watt-cap
//! monotonicity, the all-CPU counterfactual's agreement with the shared
//! measurement cache, and the drift-triggered re-adaptation loop.

use enadapt::coordinator::sched::{run_sched, run_sched_with_cache, SchedOutcome};
use enadapt::coordinator::{
    run_federated, ArrivalTrace, Drift, FederationConfig, JobConfig, SchedConfig,
    SyntheticTraceConfig,
};
use enadapt::devices::NodeSpec;
use enadapt::offload::GpuFlowConfig;
use enadapt::search::GaConfig;
use enadapt::util::measure_cache::MeasureCache;
use enadapt::verifier::AppModel;
use enadapt::workloads;
use std::sync::Arc;

/// Small-search template so GA destinations stay fast in tests.
fn quick_template() -> JobConfig {
    JobConfig {
        ga_flow: GpuFlowConfig {
            ga: GaConfig {
                population: 6,
                generations: 4,
                ..Default::default()
            },
            parallel_trials: false,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn two_node_cluster() -> Vec<NodeSpec> {
    vec![NodeSpec::r740_pac("node0"), NodeSpec::r740_pac("node1")]
}

#[test]
fn same_seed_gives_bit_identical_fleet_ledger() {
    let trace = ArrivalTrace::poisson(&SyntheticTraceConfig::standard(6, 0.5, 9));
    let cfg = SchedConfig {
        template: quick_template(),
        nodes: two_node_cluster(),
        fleet_watt_cap: Some(500.0),
        ..Default::default()
    };
    let a = run_sched(&trace, &cfg).unwrap();
    let b = run_sched(&trace, &cfg).unwrap();
    // The whole report — per-job energies, ledger totals, reconfig log —
    // must be reproducible bit for bit.
    assert_eq!(
        a.to_json().to_string_compact(),
        b.to_json().to_string_compact()
    );
    assert!(a.admitted > 0, "something must run");
    assert_eq!(a.jobs.len(), 6);
}

#[test]
fn watt_cap_sweep_is_monotone() {
    let trace = ArrivalTrace::parse(
        "0  mriq fpga\n\
         6  mriq fpga\n\
         12 mriq fpga\n\
         18 mriq fpga\n",
    )
    .unwrap();
    // Tighter fleet cap ⇒ never more admitted W·s. With two 105 W-idle
    // nodes the committed floor is 210 W: a 120 W cap admits nothing, a
    // 330 W cap admits everything one at a time, and an effectively
    // uncapped run admits the identical set (the sub-budgets stay above
    // every pattern's ~121 W peak, so the searches are unchanged).
    let mut admitted_ws = Vec::new();
    let mut admitted_n = Vec::new();
    for cap in [120.0, 330.0, 1e9] {
        let cfg = SchedConfig {
            nodes: two_node_cluster(),
            fleet_watt_cap: Some(cap),
            ..Default::default()
        };
        let r = run_sched(&trace, &cfg).unwrap();
        admitted_ws.push(r.production.total_ws());
        admitted_n.push(r.admitted);
    }
    assert_eq!(admitted_n[0], 0, "120 W cap is below the idle floor");
    assert_eq!(admitted_ws[0], 0.0);
    assert_eq!(admitted_n[1], 4, "330 W admits the whole trace");
    assert!(admitted_ws[1] > 0.0);
    // Loosening the cap never *reduces* admitted energy, and since the
    // admitted sets coincide here, the ledgers agree exactly.
    assert!(admitted_ws[0] <= admitted_ws[1]);
    assert_eq!(admitted_ws[1], admitted_ws[2], "same jobs, same energies");
}

#[test]
fn counterfactual_matches_per_job_baselines_from_the_cache() {
    let trace = ArrivalTrace::parse(
        "0 mriq fpga\n\
         3 mriq fpga 1.4\n\
         6 vecadd fpga\n",
    )
    .unwrap();
    let cfg = SchedConfig {
        nodes: two_node_cluster(),
        ..Default::default()
    };
    let cache = Arc::new(MeasureCache::new());
    let report = run_sched_with_cache(&trace, &cfg, Arc::clone(&cache)).unwrap();
    assert_eq!(report.admitted, 3);

    // Re-derive every admitted arrival's all-CPU baseline straight from
    // the shared cache the run populated: same environment fingerprint,
    // same application hash ⇒ cache hits, bit-identical energies.
    let hits_before = cache.hits();
    let mut env = cfg.template.env.clone().build(cfg.template.seed);
    env.attach_cache(Arc::clone(&cache));
    let mut by_hand = 0.0;
    for j in &report.jobs {
        let c = match &j.outcome {
            SchedOutcome::Completed(c) => c,
            SchedOutcome::Dropped { reason } => panic!("unexpected drop: {reason}"),
        };
        let (name, src) = workloads::resolve(&j.workload).unwrap();
        let an = enadapt::canalyze::analyze_source(&format!("{name}.c"), src).unwrap();
        let app = AppModel::from_analysis(&an, &cfg.template.env.cpu, 14.0 * j.scale).unwrap();
        let m = env.measure_cpu_only(&app);
        assert_eq!(m.energy_ws, c.baseline_ws, "{}@{}", j.workload, j.scale);
        by_hand += m.energy_ws;
    }
    assert!(cache.hits() > hits_before, "baselines answered by the cache");
    assert_eq!(by_hand, report.counterfactual_ws, "Σ baselines, bit-exact");
    // And the headline: the offloaded fleet beats the all-CPU fleet.
    assert!(report.production.total_ws() < report.counterfactual_ws);
}

#[test]
fn time_drifted_trace_triggers_reconfigure_and_changes_the_pattern() {
    // One FPGA deployment at the calibrated size, then the workload
    // grows 2.2× while an operator event tightens the fleet cap to
    // 220 W. The drifted observations trip the DriftMonitor (time-only:
    // the mean draw barely moves), and the re-search runs under a
    // 220 − 105 = 115 W sub-budget that every offload pattern's ≈121 W
    // host-busy peak violates — so the re-adaptation must pick a
    // different (all-CPU) pattern.
    let trace = ArrivalTrace::parse(
        "0  mriq fpga 1.0\n\
         5  cap 220\n\
         10 mriq fpga 2.2\n\
         20 mriq fpga 2.2\n\
         30 mriq fpga 2.2\n",
    )
    .unwrap();
    let cfg = SchedConfig {
        nodes: two_node_cluster(),
        ..Default::default()
    };
    let report = run_sched(&trace, &cfg).unwrap();

    assert_eq!(report.reconfigs.len(), 1, "exactly one re-search");
    let r = &report.reconfigs[0];
    assert!(matches!(r.drift, Drift::TimeDrift), "drift {:?}", r.drift);
    assert!(r.pattern_changed, "the deployed pattern must change");
    assert_ne!(r.old_pattern, r.new_pattern);
    assert!(
        r.old_pattern.contains('1'),
        "original deployment offloaded something: {}",
        r.old_pattern
    );
    assert!(
        r.new_pattern.chars().all(|c| c == '0'),
        "re-search under the tightened sub-budget falls back to all-CPU: {}",
        r.new_pattern
    );

    // The three pre-reconfiguration arrivals ran offloaded; the final
    // arrival (now an all-CPU deployment at 16 W dynamic over a 210 W
    // floor) no longer fits under the 220 W cap.
    assert_eq!(report.admitted, 3);
    assert_eq!(report.dropped, 1);
    // Cluster-wide W·s reduction vs the all-CPU counterfactual.
    assert!(
        report.jobs_reduction() > 4.0,
        "reduction {:.2} (offloaded {:.0} vs cpu {:.0} W·s)",
        report.jobs_reduction(),
        report.production.total_ws(),
        report.counterfactual_ws
    );
}

/// The event-driven engine (heaps, indexes, memoized arrivals) must fold
/// the exact report of the retained time-stepped reference loop — every
/// job energy, queue decision, drift re-search, idle split, and cache
/// counter — on a standard drifting trace, per seed.
#[test]
fn event_engine_matches_legacy_loop_bit_for_bit() {
    for seed in [7u64, 42] {
        let mut syn = SyntheticTraceConfig::standard(250, 1.0, seed);
        syn.drift_after = Some(125);
        syn.drift_scale = 2.0;
        let trace = ArrivalTrace::poisson(&syn);
        let cfg = SchedConfig {
            template: quick_template(),
            nodes: two_node_cluster(),
            fleet_watt_cap: Some(500.0),
            idle_policy: enadapt::power::IdlePolicy::gate_after(20.0),
            ..Default::default()
        };
        let event = run_sched(&trace, &cfg).unwrap();
        let legacy = run_sched(
            &trace,
            &SchedConfig {
                legacy_loop: true,
                ..cfg.clone()
            },
        )
        .unwrap();
        assert_eq!(
            event.to_json().to_string_compact(),
            legacy.to_json().to_string_compact(),
            "engines disagree at seed {seed}"
        );
        assert!(event.admitted > 0, "something must run at seed {seed}");
    }
}

/// Same equivalence on a trace with operator cap events: mid-run cap
/// tightening (queue → drop decisions), cap removal, and the drift
/// re-search under the changed sub-budget all go through the indexed
/// admission path.
#[test]
fn event_engine_matches_legacy_loop_on_cap_events() {
    let trace = ArrivalTrace::parse(
        "0  mriq fpga 1.0\n\
         5  cap 220\n\
         10 mriq fpga 2.2\n\
         20 mriq fpga 2.2\n\
         30 mriq fpga 2.2\n\
         40 cap none\n\
         45 vecadd gpu\n\
         50 vecadd gpu 1.3\n",
    )
    .unwrap();
    let cfg = SchedConfig {
        nodes: two_node_cluster(),
        ..Default::default()
    };
    let event = run_sched(&trace, &cfg).unwrap();
    let legacy = run_sched(
        &trace,
        &SchedConfig {
            legacy_loop: true,
            ..cfg
        },
    )
    .unwrap();
    assert_eq!(
        event.to_json().to_string_compact(),
        legacy.to_json().to_string_compact()
    );
    assert!(!event.reconfigs.is_empty(), "cap squeeze must trigger drift");
    assert!(event.dropped >= 1, "tightened cap must drop something");
}

#[test]
fn federated_run_is_deterministic_and_merges_cluster_ledgers() {
    let trace = ArrivalTrace::poisson(&SyntheticTraceConfig::standard(40, 0.5, 9));
    let fcfg = FederationConfig {
        base: SchedConfig {
            template: quick_template(),
            nodes: two_node_cluster(),
            fleet_watt_cap: Some(600.0),
            ..Default::default()
        },
        clusters: 4,
        shard_seed: 1,
        ..Default::default()
    };
    let a = run_federated(&trace, &fcfg).unwrap();
    let b = run_federated(&trace, &fcfg).unwrap();
    assert_eq!(
        a.to_json().to_string_compact(),
        b.to_json().to_string_compact(),
        "federation must be a pure function of (trace, config)"
    );

    // The shard partitions the arrivals: nothing lost, nothing doubled.
    assert_eq!(a.clusters.len(), 4);
    let sharded: usize = a.clusters.iter().map(|c| c.arrivals).sum();
    assert_eq!(sharded, 40);
    assert_eq!(a.admitted + a.dropped, 40);
    assert!(a.rebalanced, "a capped federation rebalances");

    // Demand shares split the whole budget.
    let share_sum: f64 = a.clusters.iter().map(|c| c.share).sum();
    assert!((share_sum - 1.0).abs() < 1e-9, "shares sum to {share_sum}");
    for c in &a.clusters {
        assert_eq!(c.cap_w, Some(600.0 * c.share));
    }

    // The merged ledger is the per-cluster sum (up to f64 association:
    // the merge adds components, the per-cluster totals add totals).
    let jobs_ws: f64 = a.clusters.iter().map(|c| c.report.production.total_ws()).sum();
    assert!(
        (a.production.total_ws() - jobs_ws).abs() <= 1e-6 * jobs_ws.max(1.0),
        "merged {} vs per-cluster sum {}",
        a.production.total_ws(),
        jobs_ws
    );
    let cf: f64 = a.clusters.iter().map(|c| c.report.counterfactual_ws).sum();
    assert_eq!(a.counterfactual_ws, cf, "counterfactual merges in order");

    // Engine independence extends to the federation: running every
    // cluster on the reference loop folds the identical federation JSON.
    let legacy_fcfg = FederationConfig {
        base: SchedConfig {
            legacy_loop: true,
            ..fcfg.base.clone()
        },
        ..fcfg
    };
    let l = run_federated(&trace, &legacy_fcfg).unwrap();
    assert_eq!(
        a.to_json().to_string_compact(),
        l.to_json().to_string_compact(),
        "federated legacy loop must match the event engine"
    );
}

/// The satellite acceptance gate: `--parallel-clusters` is a pure
/// wall-clock optimization. Per seed, the 4-cluster federation report —
/// per-cluster ledgers, merged totals, and the serial-order-reconstructed
/// cache counters — must serialize byte-identically whether the probe and
/// cluster simulations ran serially or concurrently on the thread pool.
#[test]
fn parallel_federation_is_byte_identical_to_serial_per_seed() {
    for seed in [3u64, 11] {
        let trace = ArrivalTrace::poisson(&SyntheticTraceConfig::standard(24, 0.5, seed));
        let serial_cfg = FederationConfig {
            base: SchedConfig {
                template: quick_template(),
                nodes: two_node_cluster(),
                fleet_watt_cap: Some(600.0),
                ..Default::default()
            },
            clusters: 4,
            shard_seed: seed,
            parallel: false,
            ..Default::default()
        };
        let parallel_cfg = FederationConfig {
            parallel: true,
            ..serial_cfg.clone()
        };
        let s = run_federated(&trace, &serial_cfg).unwrap();
        let p = run_federated(&trace, &parallel_cfg).unwrap();
        assert_eq!(
            s.to_json().to_string_compact(),
            p.to_json().to_string_compact(),
            "parallel federation diverged from serial at seed {seed}"
        );
        // The per-cluster SchedReports (cache counters included) must
        // also agree bit for bit, not just the merged summary.
        for (sc, pc) in s.clusters.iter().zip(&p.clusters) {
            assert_eq!(
                sc.report.to_json().to_string_compact(),
                pc.report.to_json().to_string_compact(),
                "cluster {} report diverged at seed {seed}",
                sc.cluster
            );
        }
        assert!(s.admitted > 0, "something must run at seed {seed}");
        assert!(
            s.cache_hits > 0 && s.cache_misses > 0,
            "reconstructed counters populated at seed {seed}"
        );
    }
}

/// Cap-event rebalancing: re-probing demand per cap epoch is
/// deterministic, parallel-safe, and still splits each cap across the
/// whole budget. (With no cap events in the trace there is exactly one
/// segment, so the flag is a no-op — also asserted.)
#[test]
fn rebalance_at_caps_is_deterministic_and_splits_every_cap() {
    let trace = ArrivalTrace::parse(
        "0  mriq fpga\n\
         2  vecadd gpu\n\
         6  mriq fpga 1.4\n\
         10 cap 400\n\
         14 mriq fpga\n\
         18 vecadd gpu 1.3\n\
         24 mriq fpga 2.0\n",
    )
    .unwrap();
    let cfg = FederationConfig {
        base: SchedConfig {
            template: quick_template(),
            nodes: two_node_cluster(),
            fleet_watt_cap: Some(600.0),
            ..Default::default()
        },
        clusters: 2,
        shard_seed: 5,
        parallel: false,
        rebalance_at_caps: true,
    };
    let a = run_federated(&trace, &cfg).unwrap();
    let b = run_federated(&trace, &cfg).unwrap();
    assert_eq!(
        a.to_json().to_string_compact(),
        b.to_json().to_string_compact(),
        "per-segment probing must stay deterministic"
    );
    let par = run_federated(
        &trace,
        &FederationConfig {
            parallel: true,
            ..cfg.clone()
        },
    )
    .unwrap();
    assert_eq!(
        a.to_json().to_string_compact(),
        par.to_json().to_string_compact(),
        "segmented probing must be interleaving-invariant too"
    );
    assert!(a.rebalanced);
    assert_eq!(a.admitted + a.dropped, 6);
    // Initial caps still split the whole budget by first-segment shares.
    let share_sum: f64 = a.clusters.iter().map(|c| c.share).sum();
    assert!((share_sum - 1.0).abs() < 1e-9, "shares sum to {share_sum}");
    for c in &a.clusters {
        assert_eq!(c.cap_w, Some(600.0 * c.share));
    }

    // No cap events in the trace ⇒ one segment ⇒ identical reports with
    // the flag on or off.
    let flat = ArrivalTrace::parse("0 mriq fpga\n4 vecadd gpu\n8 mriq fpga\n").unwrap();
    let off = run_federated(
        &flat,
        &FederationConfig {
            rebalance_at_caps: false,
            ..cfg.clone()
        },
    )
    .unwrap();
    let on = run_federated(&flat, &cfg).unwrap();
    assert_eq!(
        off.to_json().to_string_compact(),
        on.to_json().to_string_compact(),
        "no cap events: rebalance_at_caps must be a no-op"
    );
}

/// `--clusters 1` must be a no-op wrapper: the single cluster owns the
/// whole budget (share exactly 1.0, cap scaled bit-exactly), so its
/// report — ledger totals, per-job energies, even cache counters — is
/// the plain `run_sched` report verbatim.
#[test]
fn single_cluster_federation_matches_plain_sched_ledger() {
    let trace = ArrivalTrace::parse(
        "0  mriq fpga\n\
         6  mriq fpga 1.4\n\
         12 vecadd gpu\n\
         18 cap 400\n\
         24 mriq fpga\n",
    )
    .unwrap();
    let base = SchedConfig {
        template: quick_template(),
        nodes: two_node_cluster(),
        fleet_watt_cap: Some(500.0),
        ..Default::default()
    };
    let plain = run_sched(&trace, &base).unwrap();
    let fed = run_federated(
        &trace,
        &FederationConfig {
            base: base.clone(),
            clusters: 1,
            shard_seed: 99,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(fed.clusters.len(), 1);
    assert_eq!(fed.clusters[0].share, 1.0);
    assert_eq!(fed.clusters[0].cap_w, Some(500.0));
    assert_eq!(
        fed.clusters[0].report.to_json().to_string_compact(),
        plain.to_json().to_string_compact(),
        "one cluster, zero federation overhead — same report bit for bit"
    );
    assert_eq!(fed.admitted, plain.admitted);
    assert_eq!(fed.production.total_ws(), plain.production.total_ws());
    assert_eq!(fed.counterfactual_ws, plain.counterfactual_ws);
    assert_eq!(fed.chassis_idle_ws, plain.chassis_idle_ws);
}

#[test]
fn accelerator_idle_is_charged_and_gated_on_gpu_boxes() {
    // gpu_box nodes carry a 12 W idle draw per powered-on GPU that the
    // r740 chassis figure does not include; gating after 5 idle seconds
    // must strictly reduce the charged idle energy and report the saving.
    let trace = ArrivalTrace::parse("0 vecadd gpu\n40 vecadd gpu\n").unwrap();
    let base = SchedConfig {
        template: quick_template(),
        nodes: vec![NodeSpec::gpu_box("g0")],
        ..Default::default()
    };
    let ungated = run_sched(&trace, &base).unwrap();
    let gated_cfg = SchedConfig {
        idle_policy: enadapt::power::IdlePolicy::gate_after(5.0),
        ..base
    };
    let gated = run_sched(&trace, &gated_cfg).unwrap();

    assert_eq!(ungated.admitted, 2);
    assert!(ungated.accel_idle.charged_ws > 0.0, "idle GPUs draw power");
    assert_eq!(ungated.accel_idle.gated_ws, 0.0);
    assert!(gated.accel_idle.gated_ws > 0.0, "gating saves energy");
    assert!(
        gated.accel_idle.charged_ws < ungated.accel_idle.charged_ws,
        "gated {} vs ungated {}",
        gated.accel_idle.charged_ws,
        ungated.accel_idle.charged_ws
    );
    // Charged + gated always splits the same total idle time.
    let total_g = gated.accel_idle.charged_ws + gated.accel_idle.gated_ws;
    let total_u = ungated.accel_idle.charged_ws;
    assert!((total_g - total_u).abs() < 1e-6 * total_u.max(1.0));
    // The per-job measurements themselves are unchanged by gating.
    assert_eq!(
        ungated.production.total_ws(),
        gated.production.total_ws()
    );
}
