//! Differential tests for the lowered profiling interpreter
//! (`canalyze::lower`, DESIGN.md §13) against the tree-walking reference
//! (`canalyze::profile`): `ProfileData` and `printed` must be
//! bit-identical — MeasureCache fingerprints, sched ledgers and
//! funcblock detection all consume the profile downstream — and runtime
//! errors (bounds, zero divisors, recursion depth, the step-limit
//! runaway guard) must carry identical messages.

use enadapt::canalyze::loops::extract_loops;
use enadapt::canalyze::lower::profile_lowered;
use enadapt::canalyze::parser::parse;
use enadapt::canalyze::profile::profile;
use enadapt::canalyze::{sem, ProfileLimits};
use enadapt::util::prop::{c_program, run};
use enadapt::workloads;

/// Run both interpreters and require identical outcomes: bit-equal
/// profiles on success, equal messages on error, never a mixed pair.
fn assert_equivalent(name: &str, src: &str, limits: ProfileLimits) {
    let prog = match parse(name, src) {
        Ok(p) => p,
        Err(e) => panic!("unparseable source ({e}):\n{src}"),
    };
    if let Err(e) = sem::check(name, &prog) {
        panic!("sem-invalid source ({e}):\n{src}");
    }
    let table = extract_loops(&prog);
    let tree = profile(&prog, &table, limits);
    let lowered = profile_lowered(&prog, &table, limits);
    match (tree, lowered) {
        (Ok(t), Ok(l)) => {
            assert!(
                t.bits_eq(&l),
                "profiles diverge on {name}:\n{src}\ntree:    {t:?}\nlowered: {l:?}"
            );
        }
        (Err(te), Err(le)) => {
            assert_eq!(
                te.to_string(),
                le.to_string(),
                "error messages diverge on {name}:\n{src}"
            );
        }
        (Ok(_), Err(le)) => panic!("tree-walker ok, lowered errs ({le}) on {name}:\n{src}"),
        (Err(te), Ok(_)) => panic!("tree-walker errs ({te}), lowered ok on {name}:\n{src}"),
    }
}

#[test]
fn all_registered_workloads_are_bit_identical() {
    for (name, src) in workloads::ALL {
        let prog = parse(name, src).unwrap();
        let table = extract_loops(&prog);
        let t = profile(&prog, &table, ProfileLimits::default()).unwrap();
        let l = profile_lowered(&prog, &table, ProfileLimits::default()).unwrap();
        assert!(t.bits_eq(&l), "{name}: lowered profile diverges");
        // `printed` is the program's observable output — pin it bitwise
        // on its own so a bits_eq regression names the culprit.
        let tp: Vec<u64> = t.printed.iter().map(|x| x.to_bits()).collect();
        let lp: Vec<u64> = l.printed.iter().map(|x| x.to_bits()).collect();
        assert_eq!(tp, lp, "{name}: printed output diverges");
    }
}

#[test]
fn random_programs_are_bit_identical() {
    run("lowered vs tree-walker on random programs", 80, |g| {
        let src = c_program(g);
        assert_equivalent("prop.c", &src, ProfileLimits::default());
    });
}

#[test]
fn random_programs_agree_under_tight_step_limits() {
    // Random small step budgets drive the runaway guard through every
    // batching boundary: both interpreters must trip at the same point
    // with the same message, or both finish with bit-equal profiles.
    run("step-limit equivalence on random programs", 80, |g| {
        let src = c_program(g);
        let max_steps = g.i64_range(1, 3_000) as u64;
        assert_equivalent("prop.c", &src, ProfileLimits { max_steps, ..Default::default() });
    });
}

#[test]
fn mriq_step_limit_boundary_is_identical() {
    let src = workloads::MRIQ_C;
    let prog = parse("mriq.c", src).unwrap();
    let table = extract_loops(&prog);
    let n = profile(&prog, &table, ProfileLimits::default()).unwrap().steps;
    // Exactly at the boundary both succeed with steps == n…
    let at = ProfileLimits { max_steps: n, ..Default::default() };
    let t = profile(&prog, &table, at).unwrap();
    let l = profile_lowered(&prog, &table, at).unwrap();
    assert_eq!(t.steps, n);
    assert!(t.bits_eq(&l));
    // …and one below it both fail with the identical runaway error.
    let under = ProfileLimits { max_steps: n - 1, ..Default::default() };
    let te = profile(&prog, &table, under).unwrap_err().to_string();
    let le = profile_lowered(&prog, &table, under).unwrap_err().to_string();
    assert_eq!(te, le);
    assert!(te.contains("step limit exceeded"));
}

#[test]
fn analyze_source_uses_the_lowered_profile() {
    // The public pipeline profiles on the lowered interpreter; its output
    // must equal the reference on a program exercising calls, arrays and
    // both loop forms.
    let src = "float dot(float *a, float *b, int n) {
           float s = 0.0f;
           for (int i = 0; i < n; i++) { s += a[i] * b[i]; }
           return s;
         }
         int main() {
           float x[24];
           float y[24];
           int i = 0;
           while (i < 24) { x[i] = (float)i; y[i] = (float)(24 - i); i += 1; }
           printf(\"%f\", dot(x, y, 24));
           return 0;
         }";
    let an = enadapt::canalyze::analyze_source("dot.c", src).unwrap();
    let got = an.profile.as_ref().unwrap();
    let prog = parse("dot.c", src).unwrap();
    let table = extract_loops(&prog);
    let want = profile(&prog, &table, ProfileLimits::default()).unwrap();
    assert!(want.bits_eq(got));
    assert!(an.op_profile.is_none(), "op counting must be off by default");
}

#[test]
fn op_histogram_rides_along_without_changing_the_profile() {
    let limits = ProfileLimits { count_ops: true, ..Default::default() };
    let an = enadapt::canalyze::analyze_source_with_limits("mriq.c", workloads::MRIQ_C, limits)
        .unwrap();
    let counted = an.profile.as_ref().unwrap();
    let plain = enadapt::canalyze::analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    assert!(plain.profile.as_ref().unwrap().bits_eq(counted));
    let ops = an.op_profile.as_ref().expect("histogram requested");
    assert!(ops.total() > 0);
    assert!(!ops.top_ops(5).is_empty());
    assert!(!ops.top_pairs(5).is_empty());
}
