//! Property-based tests (via the in-tree `util::prop` harness) over the
//! coordinator's key invariants: pattern→region resolution, fitness
//! monotonicity, search-engine behaviour, Pareto-front soundness, power
//! accounting, JSON round-trips and parser/emitter fixpoints on
//! randomized programs.

use enadapt::canalyze::{analyze_source, LoopId};
use enadapt::codegen::{emit_program, Plain};
use enadapt::devices::{DeviceKind, TransferMode};
use enadapt::power::{
    AttributedProfile, ComponentPower, IpmiConfig, IpmiMeter, IpmiSampler, MeterConfig,
    OracleMeter, PowerMeter, PowerProfile, RaplConfig, RaplMeter,
};
use enadapt::search::{
    self, dominates, Crossover, FitnessSpec, GaConfig, GaStrategy, Genome, Objectives, ParetoFront,
    Scored,
};
use enadapt::util::json::{self, Json};
use enadapt::util::prng::Pcg32;
use enadapt::util::prop::{run, Gen};
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn mriq_app() -> AppModel {
    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let cfg = VerifEnvConfig::r740_pac();
    AppModel::from_analysis(&an, &cfg.cpu, 14.0).unwrap()
}

#[test]
fn prop_regions_are_disjoint_and_subsumed() {
    let app = mriq_app();
    run("regions disjoint & subsumed", 300, move |g: &mut Gen| {
        let bits = g.bits(app.genome_len());
        let regions = app.regions(&bits);
        // 1. Every region is a selected candidate.
        for r in &regions {
            let pos = app.candidates.iter().position(|c| c == r).unwrap();
            assert!(bits[pos], "region {r} not selected");
        }
        // 2. No region is an ancestor of another region.
        for a in &regions {
            for b in &regions {
                if a == b {
                    continue;
                }
                let mut p = app.loops[b.0].parent;
                while let Some(anc) = p {
                    assert_ne!(anc, *a, "region {b} nested inside region {a}");
                    p = app.loops[anc.0].parent;
                }
            }
        }
        // 3. Region count never exceeds selected count.
        let ones = bits.iter().filter(|&&b| b).count();
        assert!(regions.len() <= ones);
        // 4. Host remainder stays in [0, total].
        let rem = app.host_remainder_s(&regions);
        assert!(rem >= 0.0 && rem <= app.total_cpu_s + 1e-9);
    });
}

#[test]
fn prop_measurement_accounting_is_consistent() {
    let app = mriq_app();
    run("measurement accounting", 120, move |g: &mut Gen| {
        let bits = g.bits(app.genome_len());
        let dev = *g.pick(&[DeviceKind::Gpu, DeviceKind::Fpga, DeviceKind::ManyCore]);
        let xfer = if g.bool() {
            TransferMode::Batched
        } else {
            TransferMode::PerEntry
        };
        let env = VerifEnvConfig::r740_pac().build(g.rng().next_u64());
        let m = env.measure(&app, &bits, dev, xfer);
        assert!(m.time_s > 0.0);
        assert!(m.mean_w > 0.0);
        // Trapezoidal energy must equal mean power × duration (identity).
        let dur = m.trace.duration_s();
        if dur > 0.0 {
            let recomputed = m.mean_w * dur;
            assert!(
                (recomputed - m.energy_ws).abs() <= 1e-6 * m.energy_ws.max(1.0),
                "energy {} vs mean*dur {}",
                m.energy_ws,
                recomputed
            );
        }
        // Power bounded by idle and idle + all-device ceiling.
        assert!(m.mean_w >= env.cfg.server.idle_w - 10.0);
        assert!(m.mean_w <= env.cfg.server.idle_w + 160.0);
        // Breakdown sums to roughly the wall time.
        let sum = m.breakdown.cpu_s + m.breakdown.transfer_s + m.breakdown.kernel_s;
        assert!((sum - m.time_s).abs() <= 1e-6 * m.time_s.max(1.0));
    });
}

#[test]
fn prop_fitness_monotone_in_time_and_power() {
    run("fitness monotonicity", 500, |g: &mut Gen| {
        let spec = FitnessSpec::paper();
        let t = g.f64_pos(0.1, 900.0);
        let p = g.f64_pos(10.0, 400.0);
        let dt = g.f64_pos(0.01, 100.0);
        let dp = g.f64_pos(0.1, 100.0);
        assert!(spec.value(t, p, false) > spec.value(t + dt, p, false));
        assert!(spec.value(t, p, false) > spec.value(t, p + dp, false));
        // Timeout is always at least as bad as any clean sub-timeout run.
        assert!(spec.value(t.min(179.0), p, false) >= spec.value(t.min(179.0), p, true));
    });
}

#[test]
fn prop_ga_respects_genome_space() {
    run("ga genome space", 25, |g: &mut Gen| {
        let len = g.usize_range(2, 12);
        let pop = g.usize_range(4, 12);
        let gens = g.usize_range(2, 8);
        let seed = g.rng().next_u64();
        let cfg = GaConfig {
            population: pop,
            generations: gens,
            ..Default::default()
        };
        let mut evals = 0usize;
        let r = search::run_synthetic(&GaStrategy { cfg }, len, seed, |genome| {
            evals += 1;
            assert_eq!(genome.len(), len);
            genome.ones() as f64
        })
        .unwrap();
        assert_eq!(r.best.len(), len);
        // Measure-once: distinct evaluations bounded by the space size.
        assert!(evals <= 1usize << len.min(20));
        assert_eq!(evals, r.measured);
        // Best history is monotone.
        for w in r.history.windows(2) {
            assert!(w[1].best >= w[0].best);
        }
    });
}

#[test]
fn prop_pareto_front_is_sound_and_complete() {
    run("pareto front soundness", 150, |g: &mut Gen| {
        // Random point cloud with distinct genomes.
        let n = g.usize_range(1, 40);
        let mut pts: Vec<Scored> = Vec::with_capacity(n);
        for i in 0..n {
            let o = Objectives {
                time_s: g.f64_pos(0.5, 20.0),
                energy_ws: g.f64_pos(50.0, 2000.0),
                peak_w: g.f64_pos(100.0, 250.0),
                measured_peak_w: g.f64_pos(100.0, 250.0),
                mean_w: g.f64_pos(50.0, 250.0),
                timed_out: false,
            };
            pts.push(Scored {
                genome: Genome::from_index(8, i),
                objectives: o,
            });
        }
        let front = ParetoFront::of(&pts);
        assert!(!front.is_empty());
        // Soundness: no front member dominates another.
        for a in &front.points {
            for b in &front.points {
                if a.genome != b.genome {
                    assert!(!dominates(&a.objectives, &b.objectives));
                }
            }
        }
        // Completeness: every excluded point is dominated by some front
        // member; every non-dominated point is on the front.
        for p in &pts {
            let on_front = front.contains(&p.genome);
            let dominated = pts
                .iter()
                .any(|q| q.genome != p.genome && dominates(&q.objectives, &p.objectives));
            assert_eq!(on_front, !dominated, "point {}", p.genome);
        }
        // The knee is a front member with the maximal scalarized value
        // over the whole cloud (scalarization-last loses nothing for the
        // paper spec's monotone value).
        let knee = front.knee(&FitnessSpec::time_only()).unwrap();
        let best_time = pts
            .iter()
            .map(|p| p.objectives.time_s)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(knee.objectives.time_s, best_time);
    });
}

#[test]
fn prop_crossover_conserves_and_mutation_bounds() {
    run("crossover/mutation invariants", 300, |g: &mut Gen| {
        let len = g.usize_range(2, 24);
        let mut rng = Pcg32::seed_from_u64(g.rng().next_u64());
        let a = Genome::random(len, 0.5, &mut rng);
        let b = Genome::random(len, 0.5, &mut rng);
        let op = *g.pick(&[Crossover::OnePoint, Crossover::TwoPoint, Crossover::Uniform]);
        let (c, d) = op.apply(&a, &b, &mut rng);
        for i in 0..len {
            assert_eq!(
                a.bits[i] as u8 + b.bits[i] as u8,
                c.bits[i] as u8 + d.bits[i] as u8,
                "bit multiset at {i}"
            );
        }
        let mut m = c.clone();
        search::mutate(&mut m, 0.0, &mut rng);
        assert_eq!(m, c, "zero-rate mutation is identity");
    });
}

#[test]
fn prop_power_trace_energy_close_to_profile() {
    run("ipmi energy ≈ exact energy", 150, |g: &mut Gen| {
        let mut profile = PowerProfile::new();
        let phases = g.usize_range(1, 6);
        for _ in 0..phases {
            profile.push(g.f64_pos(0.5, 20.0), g.f64_pos(50.0, 300.0));
        }
        let sampler = IpmiSampler::new(IpmiConfig {
            period_s: 1.0,
            noise_w_std: 0.0,
            quantum_w: 0.0,
        });
        let mut rng = Pcg32::seed_from_u64(g.rng().next_u64());
        let trace = sampler.sample(&profile, &mut rng);
        let exact = profile.energy_ws();
        let sampled = trace.energy_ws();
        // 1 Hz sampling of piecewise-constant power: error bounded by one
        // sample period's worth of the max power swing per phase boundary.
        let tol = 0.5 + (phases as f64) * 300.0;
        assert!(
            (sampled - exact).abs() <= tol,
            "sampled {sampled} vs exact {exact} (phases {phases})"
        );
        assert!(trace.peak_w() <= 300.0 + 1e-9);
    });
}

/// Random component-tagged profile: 1–6 phases with idle-dominated draw
/// (the shape every verification trial produces).
fn gen_attributed_profile(g: &mut Gen) -> AttributedProfile {
    let mut p = AttributedProfile::new();
    let phases = g.usize_range(1, 6);
    for _ in 0..phases {
        p.push(
            g.f64_pos(0.5, 10.0),
            ComponentPower {
                idle_w: g.f64_pos(50.0, 200.0),
                host_cpu_w: g.f64_range(0.0, 50.0),
                accelerator_w: g.f64_range(0.0, 150.0),
                transfer_w: g.f64_range(0.0, 20.0),
            },
        );
    }
    p
}

/// Analytic bound on trapezoid-vs-exact error for a piecewise-constant
/// profile sampled at period `p`: each phase boundary contributes at most
/// one mis-integrated interval of the power swing, plus one partial
/// interval at the end.
fn sampling_error_bound(profile: &AttributedProfile, period: f64) -> f64 {
    let totals: Vec<f64> = profile.phases().iter().map(|ph| ph.1.total_w()).collect();
    let swings: f64 = totals.windows(2).map(|w| (w[1] - w[0]).abs()).sum();
    let max_w = totals.iter().cloned().fold(0.0, f64::max);
    period * (swings + max_w)
}

#[test]
fn prop_sampled_energy_converges_to_exact_with_meter_rate() {
    run("meter-rate convergence", 120, |g: &mut Gen| {
        let profile = gen_attributed_profile(g);
        let exact = profile.flatten().energy_ws();
        let dur = profile.duration_s();
        let mut rng = Pcg32::seed_from_u64(g.rng().next_u64());
        // Oracle: exact by construction (bit-identical to the profile).
        let oracle = OracleMeter.measure(&profile, &mut rng);
        assert_eq!(oracle.report.energy_ws, exact, "oracle must be exact");
        // Noise-free sampling at increasing rates: the error obeys the
        // analytic bound, which shrinks linearly with the period.
        for divisor in [8.0, 64.0, 512.0] {
            let period = dur / divisor;
            let meter = IpmiMeter::new(IpmiConfig {
                period_s: period,
                noise_w_std: 0.0,
                quantum_w: 0.0,
            });
            let m = meter.measure(&profile, &mut rng);
            let err = (m.report.energy_ws - exact).abs();
            let bound = sampling_error_bound(&profile, period);
            assert!(
                err <= bound + 1e-9,
                "period {period}: err {err} > bound {bound} (exact {exact})"
            );
        }
        // At the finest rate the bound itself is small: convergence.
        let fine_bound = sampling_error_bound(&profile, dur / 512.0);
        assert!(
            fine_bound < 0.12 * exact,
            "bound {fine_bound} vs exact {exact}"
        );
    });
}

#[test]
fn prop_all_meter_backends_agree_and_attribute_consistently() {
    run("meter backend agreement", 80, |g: &mut Gen| {
        let profile = gen_attributed_profile(g);
        let exact = profile.flatten().energy_ws();
        let dur = profile.duration_s();
        let seed = g.rng().next_u64();
        // Noise-free RAPL sampling error obeys the same analytic bound;
        // default (noisy) RAPL adds the clamped-noise bias, covered by a
        // 1 W·s-per-second margin.
        let cases: Vec<(Box<dyn PowerMeter>, f64)> = vec![
            (Box::new(OracleMeter), 0.0),
            (
                Box::new(IpmiMeter::new(IpmiConfig {
                    period_s: 0.25,
                    noise_w_std: 0.0,
                    quantum_w: 0.0,
                })),
                sampling_error_bound(&profile, 0.25),
            ),
            (
                Box::new(RaplMeter::new(RaplConfig::default())),
                sampling_error_bound(&profile, RaplConfig::default().period_s) + 1.0 * dur,
            ),
        ];
        for (meter, tol) in cases {
            let mut rng = Pcg32::seed_from_u64(seed);
            let m = meter.measure(&profile, &mut rng);
            let err = (m.report.energy_ws - exact).abs();
            assert!(
                err <= tol + 1e-9,
                "{}: energy {} vs exact {} (tol {})",
                meter.name(),
                m.report.energy_ws,
                exact,
                tol
            );
            // Attribution invariant: components sum to the whole-server
            // total within 1e-6 on every backend.
            let sum = m.report.components.total_ws();
            assert!(
                (sum - m.report.energy_ws).abs() <= 1e-6 * m.report.energy_ws.max(1.0),
                "{}: components {} vs total {}",
                meter.name(),
                sum,
                m.report.energy_ws
            );
            assert!(m.report.peak_w >= 0.0 && m.report.time_s == m.trace.duration_s());
        }
    });
}

#[test]
fn meter_backends_agree_on_fig5_bands() {
    // The DESIGN.md §1 bands are asserted under the default IPMI meter by
    // the unit tests; every other backend must reproduce them too, and
    // all backends must agree with the oracle within sampling tolerance.
    let app = mriq_app();
    let best_bits = {
        let outer = app
            .loops
            .iter()
            .max_by(|a, b| a.cpu_time_s.partial_cmp(&b.cpu_time_s).unwrap())
            .unwrap()
            .id;
        let pos = app.candidates.iter().position(|&c| c == outer).unwrap();
        let mut bits = vec![false; app.genome_len()];
        bits[pos] = true;
        bits
    };
    let mut energies = Vec::new();
    for name in ["ipmi", "rapl", "oracle"] {
        let mut cfg = VerifEnvConfig::r740_pac();
        cfg.meter = MeterConfig::from_name(name).unwrap();
        let env = cfg.build(42);
        let cpu = env.measure_cpu_only(&app);
        let fpga = env.measure(&app, &best_bits, DeviceKind::Fpga, TransferMode::Batched);
        assert!((13.0..15.5).contains(&cpu.time_s), "{name} time {}", cpu.time_s);
        assert!((118.0..124.0).contains(&cpu.mean_w), "{name} power {}", cpu.mean_w);
        assert!(
            (1500.0..1900.0).contains(&cpu.energy_ws),
            "{name} energy {}",
            cpu.energy_ws
        );
        assert!(
            (150.0..360.0).contains(&fpga.energy_ws),
            "{name} offl energy {}",
            fpga.energy_ws
        );
        let ratio = cpu.energy_ws / fpga.energy_ws;
        assert!((4.0..12.0).contains(&ratio), "{name} ratio {ratio}");
        energies.push((cpu.energy_ws, fpga.energy_ws));
    }
    // Pairwise agreement: CPU-only within 5%, the short offloaded trace
    // within 20% (1 Hz IPMI only gets a few samples of it).
    for (a, b) in energies.iter().zip(energies.iter().skip(1)) {
        assert!((a.0 / b.0 - 1.0).abs() < 0.05, "cpu {} vs {}", a.0, b.0);
        assert!((a.1 / b.1 - 1.0).abs() < 0.20, "fpga {} vs {}", a.1, b.1);
    }
}

#[test]
fn prop_json_roundtrip() {
    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        if depth == 0 {
            return match g.usize_range(0, 3) {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.i64_range(-1_000_000, 1_000_000)) as f64),
                _ => Json::Str(format!("s{}", g.i64_range(0, 999))),
            };
        }
        match g.usize_range(0, 5) {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num(g.f64_range(-1e6, 1e6)),
            3 => Json::Str(format!("k\"é\n{}", g.i64_range(0, 99))),
            4 => Json::Arr((0..g.usize_range(0, 4)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_range(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    run("json roundtrip", 300, |g: &mut Gen| {
        let v = gen_json(g, 3);
        let compact = json::parse(&v.to_string_compact()).unwrap();
        let pretty = json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(json_eq(&v, &compact), true, "compact");
        assert_eq!(json_eq(&v, &pretty), true, "pretty");
    });
}

/// Structural equality with float tolerance (serialization may shorten).
fn json_eq(a: &Json, b: &Json) -> bool {
    match (a, b) {
        (Json::Num(x), Json::Num(y)) => (x - y).abs() <= 1e-9 * x.abs().max(1.0),
        (Json::Arr(xs), Json::Arr(ys)) => {
            xs.len() == ys.len() && xs.iter().zip(ys).all(|(x, y)| json_eq(x, y))
        }
        (Json::Obj(xs), Json::Obj(ys)) => {
            xs.len() == ys.len()
                && xs
                    .iter()
                    .zip(ys)
                    .all(|((ka, va), (kb, vb))| ka == kb && json_eq(va, vb))
        }
        _ => a == b,
    }
}

#[test]
fn prop_emit_parse_fixpoint_on_random_programs() {
    run("emit→parse fixpoint", 80, |g: &mut Gen| {
        let src = random_program(g);
        let p1 = match enadapt::canalyze::parser::parse("rand.c", &src) {
            Ok(p) => p,
            Err(e) => panic!("generator produced unparsable source: {e}\n{src}"),
        };
        let emitted = emit_program(&p1, &Plain);
        let p2 = enadapt::canalyze::parser::parse("rand2.c", &emitted)
            .unwrap_or_else(|e| panic!("emitted source unparsable: {e}\n{emitted}"));
        assert_eq!(p1.n_loops, p2.n_loops);
        // Emission is a fixpoint after one round trip.
        let emitted2 = emit_program(&p2, &Plain);
        assert_eq!(emitted, emitted2);
    });
}

/// Generate a small random-but-valid C-subset program.
fn random_program(g: &mut Gen) -> String {
    let mut src = String::from("void f(float *a, float *b, int n) {\n");
    let n_stmts = g.usize_range(1, 5);
    for i in 0..n_stmts {
        src.push_str(&random_stmt(g, i, 1));
    }
    src.push_str("}\n");
    src
}

fn random_expr(g: &mut Gen, idx_var: &str) -> String {
    match g.usize_range(0, 4) {
        0 => format!("a[{idx_var}]"),
        1 => format!("b[{idx_var}]"),
        2 => format!("{}.5f", g.i64_range(0, 9)),
        3 => format!("sinf(a[{idx_var}])"),
        _ => format!("(a[{idx_var}] + {}.0f)", g.i64_range(1, 5)),
    }
}

fn random_stmt(g: &mut Gen, uniq: usize, depth: usize) -> String {
    let pad = "  ".repeat(depth);
    match g.usize_range(0, 3) {
        0 => {
            let e = random_expr(g, "0");
            format!("{pad}float t{uniq} = {e};\n")
        }
        1 => {
            let v = format!("i{uniq}");
            let body = format!(
                "{pad}  a[{v}] = {};\n",
                random_expr(g, &v).replace("a[", "b[") // avoid self-alias noise
            );
            format!(
                "{pad}for (int {v} = 0; {v} < n; {v}++) {{\n{body}{pad}}}\n"
            )
        }
        2 => {
            let e = random_expr(g, "0");
            format!("{pad}if (n > {}) {{ b[0] = {e}; }}\n", g.i64_range(0, 9))
        }
        _ => {
            let e = random_expr(g, "0");
            format!("{pad}b[1] = {e};\n")
        }
    }
}

#[test]
fn prop_transfer_plan_mode_consistency() {
    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let candidates: Vec<LoopId> = an.parallelizable_ids();
    run("transfer plan consistency", 150, move |g: &mut Gen| {
        let k = g.usize_range(1, 4.min(candidates.len()));
        let mut picked = Vec::new();
        for _ in 0..k {
            let c = *g.pick(&candidates);
            if !picked.contains(&c) {
                picked.push(c);
            }
        }
        let plan = enadapt::offload::transfer_plan(&an, &picked);
        let all_batched = plan
            .arrays
            .values()
            .all(|t| *t == enadapt::offload::ArrayTransfer::BatchedOnce);
        assert_eq!(
            plan.mode() == TransferMode::Batched,
            all_batched,
            "mode must reflect per-array verdicts"
        );
        assert_eq!(plan.batched_count() == plan.arrays.len(), all_batched);
    });
}
