//! Fleet-coordinator integration tests: determinism vs the serial
//! single-job path, cross-job and cross-invocation trial deduplication via
//! the shared measurement cache, and matrix coverage.

use enadapt::coordinator::{
    fleet, run_fleet, run_job, Destination, FleetConfig, FleetSpec, JobConfig, JobReport,
};
use enadapt::devices::DeviceKind;
use enadapt::search::GaConfig;
use enadapt::offload::GpuFlowConfig;
use enadapt::util::json::Json;
use enadapt::workloads;

fn quick_template() -> JobConfig {
    JobConfig {
        ga_flow: GpuFlowConfig {
            ga: GaConfig {
                population: 6,
                generations: 4,
                ..Default::default()
            },
            parallel_trials: false,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn small_matrix() -> Vec<FleetSpec> {
    let mut specs = Vec::new();
    for workload in ["mriq", "vecadd"] {
        for dest in [
            Destination::Device(DeviceKind::Gpu),
            Destination::Device(DeviceKind::Fpga),
        ] {
            let (_, src) = workloads::ALL
                .iter()
                .find(|(n, _)| *n == workload)
                .unwrap();
            specs.push(FleetSpec {
                workload: workload.to_string(),
                source: src.to_string(),
                destination: dest,
            });
        }
    }
    specs
}

/// Canonical per-job result: the fields the acceptance criterion pins
/// (chosen pattern, device, W·s) plus time/value for good measure.
fn canonical(r: &JobReport) -> String {
    Json::obj(vec![
        ("pattern", Json::str(r.best.pattern.genome.to_string())),
        ("device", Json::str(r.device.name())),
        ("value", Json::num(r.best.value)),
        ("time_s", Json::num(r.production.time_s)),
        ("mean_w", Json::num(r.production.mean_w)),
        ("energy_ws", Json::num(r.production.energy_ws)),
        ("baseline_energy_ws", Json::num(r.baseline.energy_ws)),
    ])
    .to_string_compact()
}

#[test]
fn fleet_results_are_byte_identical_to_serial_run_job() {
    let specs = small_matrix();
    let cfg = FleetConfig {
        template: quick_template(),
        workers: 4,
        ..Default::default()
    };
    let report = run_fleet(&specs, &cfg).unwrap();
    assert!(report.cache_hits > 0, "fleet must share trials across jobs");

    for (spec, outcome) in specs.iter().zip(&report.jobs) {
        let mut jc = quick_template();
        jc.destination = spec.destination;
        let serial = run_job(&spec.workload, &spec.source, &jc).unwrap();
        let fleet_report = outcome.report.as_ref().unwrap();
        assert_eq!(
            canonical(fleet_report),
            canonical(&serial),
            "{} on {:?} diverged from the serial path",
            spec.workload,
            spec.destination
        );
    }
}

#[test]
fn fleet_cache_persists_across_invocations() {
    let dir = std::env::temp_dir().join("enadapt_fleet_cache_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fleet_cache.json");
    let _ = std::fs::remove_file(&path);

    let specs = small_matrix();
    let cfg = FleetConfig {
        template: quick_template(),
        workers: 2,
        cache_path: Some(path.clone()),
        ..Default::default()
    };

    let first = run_fleet(&specs, &cfg).unwrap();
    assert_eq!(first.cache_preloaded, 0);
    assert!(first.cache_misses > 0);
    assert!(path.exists(), "cache file written");

    // Second invocation: every trial of the identical run is preloaded.
    let second = run_fleet(&specs, &cfg).unwrap();
    assert!(second.cache_preloaded > 0, "cache reloaded from disk");
    assert_eq!(
        second.cache_misses, 0,
        "identical rerun must be fully served by the persisted cache"
    );
    for (a, b) in first.jobs.iter().zip(&second.jobs) {
        assert_eq!(
            canonical(a.report.as_ref().unwrap()),
            canonical(b.report.as_ref().unwrap()),
            "persisted trials changed a result"
        );
    }
    let _ = std::fs::remove_file(&path);
}

/// The append-only measurement log pools trials across invocations
/// *without* a snapshot: the first run appends every completed
/// measurement as it lands, the second replays the log and re-measures
/// nothing, and compaction folds the records into a v3 snapshot that a
/// snapshot-only run then preloads.
#[test]
fn fleet_cache_log_pools_measurements_and_compacts() {
    let dir = std::env::temp_dir().join("enadapt_fleet_cache_log_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join("measure.log");
    let snap = dir.join("cache.json");

    let specs: Vec<FleetSpec> = small_matrix().into_iter().take(2).collect();
    let cfg = FleetConfig {
        template: quick_template(),
        workers: 2,
        cache_log: Some(log.clone()),
        ..Default::default()
    };

    let first = run_fleet(&specs, &cfg).unwrap();
    assert_eq!(first.cache_preloaded, 0);
    assert!(first.cache_misses > 0);
    let records = std::fs::read_to_string(&log)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .count();
    assert_eq!(
        records as u64, first.cache_misses,
        "one flushed log record per completed measurement"
    );

    // Second invocation replays the log: everything preloaded, nothing
    // re-measured, identical results.
    let second = run_fleet(&specs, &cfg).unwrap();
    assert_eq!(second.cache_preloaded, first.cache_entries);
    assert_eq!(second.cache_misses, 0, "log replay serves every trial");
    for (a, b) in first.jobs.iter().zip(&second.jobs) {
        assert_eq!(
            canonical(a.report.as_ref().unwrap()),
            canonical(b.report.as_ref().unwrap()),
            "log-pooled trials changed a result"
        );
    }

    // Compact the log into a snapshot and run snapshot-only.
    let stats =
        enadapt::util::measure_cache::MeasureCache::compact(&log, &snap).unwrap();
    assert_eq!(stats.entries, first.cache_entries);
    assert_eq!(std::fs::metadata(&log).unwrap().len(), 0, "log truncated");
    let snap_cfg = FleetConfig {
        cache_path: Some(snap),
        cache_log: None,
        ..cfg
    };
    let third = run_fleet(&specs, &snap_cfg).unwrap();
    assert_eq!(third.cache_preloaded, first.cache_entries);
    assert_eq!(third.cache_misses, 0, "compacted snapshot serves every trial");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unshared_cache_fleet_still_matches_serial() {
    let specs: Vec<FleetSpec> = small_matrix().into_iter().take(2).collect();
    let cfg = FleetConfig {
        template: quick_template(),
        workers: 2,
        share_cache: false,
        ..Default::default()
    };
    let report = run_fleet(&specs, &cfg).unwrap();
    assert_eq!(report.cache_hits, 0, "no shared cache, no hits");
    for (spec, outcome) in specs.iter().zip(&report.jobs) {
        let mut jc = quick_template();
        jc.destination = spec.destination;
        let serial = run_job(&spec.workload, &spec.source, &jc).unwrap();
        assert_eq!(
            canonical(outcome.report.as_ref().unwrap()),
            canonical(&serial)
        );
    }
}

#[test]
fn fleet_report_aggregates_are_consistent() {
    let specs = small_matrix();
    let cfg = FleetConfig {
        template: quick_template(),
        workers: 2,
        ..Default::default()
    };
    let report = run_fleet(&specs, &cfg).unwrap();
    assert_eq!(report.workers, 2);
    assert!(report.wall_s > 0.0);
    assert!(report.serial_wall_s >= report.wall_s * 0.5, "sanity");
    assert!(report.jobs_per_s() > 0.0);
    assert!((0.0..=1.0).contains(&report.hit_rate()));
    let table = report.table();
    assert!(table.contains("mriq"));
    assert!(table.contains("hit rate"));
    // The matrix helper covers every workload and destination.
    assert_eq!(fleet::full_matrix().len(), workloads::ALL.len() * 4);
}
