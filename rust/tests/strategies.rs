//! Strategy-layer integration tests: parity between GA / annealing /
//! exhaustive on every small workload space, exhaustive-vs-brute-force
//! agreement through the shared measurement cache, and the MRI-Q
//! exhaustive Pareto front containing the paper's Fig. 5 endpoints.

use enadapt::canalyze::analyze_source;
use enadapt::devices::{DeviceKind, TransferMode};
use enadapt::offload::{gpu_flow, GpuFlowConfig};
use enadapt::search::{dominates, AnnealConfig, FitnessSpec, GaConfig, Genome, SearchStrategy};
use enadapt::util::measure_cache::MeasureCache;
use enadapt::verifier::{AppModel, VerifEnv, VerifEnvConfig};
use enadapt::workloads;
use std::sync::Arc;

fn app_env(name: &str, src: &str, baseline_s: f64, seed: u64) -> (AppModel, VerifEnv) {
    let an = analyze_source(name, src).unwrap();
    let cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &cfg.cpu, baseline_s).unwrap();
    (app, cfg.build(seed))
}

fn flow_cfg(strategy: SearchStrategy) -> GpuFlowConfig {
    GpuFlowConfig {
        ga: GaConfig {
            population: 10,
            generations: 8,
            ..Default::default()
        },
        strategy,
        parallel_trials: false,
        ..Default::default()
    }
}

/// On every workload whose pattern space fits in 8 bits, the exhaustive
/// strategy is ground truth: GA and annealing share its trial purity
/// (same env seed → identical per-pattern measurements), so their best
/// scalarized value can never exceed the exhaustive optimum.
#[test]
fn exhaustive_bounds_ga_and_anneal_on_small_spaces() {
    let mut tested = 0usize;
    for (name, src) in workloads::ALL {
        let (app, _) = app_env(name, src, 5.0, 7);
        let len = app.genome_len();
        if len > 8 {
            continue;
        }
        tested += 1;
        for device in [DeviceKind::Gpu, DeviceKind::ManyCore] {
            let run = |strategy: SearchStrategy| {
                let (app, env) = app_env(name, src, 5.0, 7);
                gpu_flow::run_on(&app, &env, &flow_cfg(strategy), device).unwrap()
            };
            let ex = run(SearchStrategy::Exhaustive { max_bits: 8 });
            let ga = run(SearchStrategy::Ga);
            let an = run(SearchStrategy::Anneal(AnnealConfig::default()));
            assert_eq!(ex.search.measured, 1usize << len, "{name}/{device}");
            assert!(
                ga.best.value <= ex.best.value,
                "{name}/{device}: ga {} beats exhaustive {}",
                ga.best.value,
                ex.best.value
            );
            assert!(
                an.best.value <= ex.best.value,
                "{name}/{device}: anneal {} beats exhaustive {}",
                an.best.value,
                ex.best.value
            );
            // All three searched the same space with the same guide.
            assert_eq!(ga.search.strategy, "ga");
            assert_eq!(an.search.strategy, "anneal");
            assert_eq!(ex.search.strategy, "exhaustive");
        }
    }
    assert!(tested >= 1, "no bundled workload has a ≤8-bit space");
}

/// The exhaustive winner must agree with a brute-force recomputation
/// straight from the cached Measurements: every re-lookup is a cache hit
/// (no new trials), and the strict argmax over index order reproduces the
/// strategy's best value and genome exactly.
#[test]
fn exhaustive_agrees_with_brute_force_over_cached_measurements() {
    let mut tested = 0usize;
    for (name, src) in workloads::ALL {
        let (probe, _) = app_env(name, src, 5.0, 3);
        let len = probe.genome_len();
        if len > 8 {
            continue;
        }
        tested += 1;
        let cache = Arc::new(MeasureCache::new());
        let (app, mut env) = app_env(name, src, 5.0, 3);
        env.attach_cache(Arc::clone(&cache));
        let out = gpu_flow::run_on(
            &app,
            &env,
            &flow_cfg(SearchStrategy::Exhaustive { max_bits: 8 }),
            DeviceKind::Gpu,
        )
        .unwrap();

        let spec = FitnessSpec::paper();
        let trials_before = env.trials_run();
        let mut best_v = f64::NEG_INFINITY;
        let mut best_g = Genome::zeros(len);
        for idx in 0..(1usize << len) {
            let g = Genome::from_index(len, idx);
            let m = if g.ones() == 0 {
                env.measure_cpu_only(&app)
            } else {
                env.measure(&app, &g.bits, DeviceKind::Gpu, TransferMode::Batched)
            };
            let v = spec.value_of(&m);
            if v > best_v {
                best_v = v;
                best_g = g;
            }
        }
        assert_eq!(
            env.trials_run(),
            trials_before,
            "{name}: brute force re-ran a trial (cache miss)"
        );
        assert_eq!(out.best.value, best_v, "{name}: value drifted");
        assert_eq!(out.best.pattern.genome, best_g, "{name}: genome drifted");
    }
    assert!(tested >= 1, "no bundled workload has a ≤8-bit space");
}

/// The acceptance check of the Pareto layer: exhausting MRI-Q's full
/// 16-bit space against the FPGA yields a front that contains both Fig. 5
/// endpoints — the all-CPU baseline (strictly lowest exact peak draw) and
/// the paper's offloaded point (lowest energy, the default
/// scalarization's knee) — and the knee stays inside the Fig. 5 bands.
#[test]
fn exhaustive_front_on_mriq_has_baseline_and_paper_point() {
    let (app, env) = app_env("mriq.c", workloads::MRIQ_C, 14.0, 42);
    let out = gpu_flow::run_on(
        &app,
        &env,
        &flow_cfg(SearchStrategy::Exhaustive { max_bits: 16 }),
        DeviceKind::Fpga,
    )
    .unwrap();
    assert_eq!(out.search.measured, 1usize << 16, "whole space measured");

    let front = &out.search.front;
    assert!(front.len() >= 2, "front {}", front.len());
    assert!(
        front.points.iter().any(|s| s.genome.ones() == 0),
        "front lacks the all-CPU baseline"
    );
    // The knee pick is on the front and lands in the Fig. 5 bands
    // (DESIGN.md §1): the paper's offloaded point.
    assert!(front.contains(&out.best.pattern.genome), "knee not on front");
    assert!(
        (1.2..3.5).contains(&out.best.measurement.time_s),
        "time {}",
        out.best.measurement.time_s
    );
    assert!(
        (150.0..360.0).contains(&out.best.measurement.energy_ws),
        "energy {}",
        out.best.measurement.energy_ws
    );
    assert!(
        out.best.value >= out.baseline_value,
        "exhaustive best below baseline"
    );
    // Soundness: pairwise non-dominated.
    for a in &front.points {
        for b in &front.points {
            if a.genome != b.genome {
                assert!(
                    !dominates(&a.objectives, &b.objectives),
                    "{} dominates {}",
                    a.genome,
                    b.genome
                );
            }
        }
    }
}

/// Strategy choice routes through the coordinator pipeline: a non-GA
/// strategy on the FPGA destination bypasses the narrowing funnel and
/// searches the device directly, and the report carries the label.
#[test]
fn pipeline_routes_fpga_strategies() {
    use enadapt::coordinator::{run_job, Destination, JobConfig};
    let mut cfg = JobConfig {
        destination: Destination::Device(DeviceKind::Fpga),
        ..Default::default()
    };
    cfg.ga_flow.strategy = SearchStrategy::Anneal(AnnealConfig {
        steps: 64,
        ..Default::default()
    });
    cfg.ga_flow.parallel_trials = false;
    let job = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
    assert_eq!(job.strategy, "anneal");
    assert!(!job.front.is_empty());

    let default_job = run_job("mriq.c", workloads::MRIQ_C, &JobConfig::default()).unwrap();
    assert_eq!(default_job.strategy, "narrowing", "GA keeps the §3.2 funnel");
}
