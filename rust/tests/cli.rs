//! CLI tests: drive the real `enadapt` binary end-to-end (cargo builds it
//! for integration tests and exposes the path via `CARGO_BIN_EXE_*`).

use std::process::Command;

fn enadapt(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_enadapt"))
        .args(args)
        .output()
        .expect("spawn enadapt")
}

/// Every subcommand the CLI exposes, in help order. The snapshot below
/// and the README drift check both key off this list — extending the CLI
/// means updating all three together.
const COMMANDS: [&str; 11] = [
    "analyze",
    "blocks",
    "offload",
    "fleet",
    "sched",
    "cache",
    "power",
    "codegen",
    "calibrate",
    "report",
    "obs",
];

#[test]
fn help_lists_commands() {
    let out = enadapt(&["--help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in COMMANDS {
        assert!(text.contains(cmd), "missing {cmd}");
    }
}

#[test]
fn help_snapshot_matches_declared_commands() {
    // Snapshot of the COMMANDS section: one `  <name>  <about…>` line per
    // subcommand, in declaration order, and nothing else. Fails when a
    // command is added/renamed without updating the docs layer.
    let out = enadapt(&["--help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    let section = text
        .split("COMMANDS:")
        .nth(1)
        .expect("help has a COMMANDS section")
        .split("\n\n")
        .next()
        .unwrap();
    let listed: Vec<&str> = section
        .lines()
        .filter_map(|l| l.split_whitespace().next())
        .collect();
    assert_eq!(listed, COMMANDS, "help snapshot drifted");
}

#[test]
fn analyze_mriq_reports_16_of_19() {
    let out = enadapt(&["analyze", "mriq"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("16 of 19 loop statements are processable"), "{text}");
    assert!(text.contains("computeQ"));
}

#[test]
fn analyze_json_is_valid() {
    let out = enadapt(&["analyze", "mriq", "--json"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    let j = enadapt::util::json::parse(&text).expect("valid json");
    assert_eq!(j.get("processable").unwrap().as_f64(), Some(16.0));
    assert_eq!(j.get("n_loops").unwrap().as_f64(), Some(19.0));
}

#[test]
fn offload_fpga_prints_fig5() {
    let out = enadapt(&["offload", "mriq", "--dest", "fpga"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Step 7"));
    assert!(text.contains("Fig. 5"));
    assert!(text.contains("energy reduction"));
}

#[test]
fn offload_json_has_production_numbers() {
    let out = enadapt(&[
        "offload", "mriq", "--dest", "gpu", "--json", "--generations", "4", "--population", "6",
    ]);
    assert!(out.status.success());
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    let prod = j.get("production").unwrap();
    assert!(prod.get("time_s").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(j.get("device").unwrap().as_str(), Some("gpu"));
}

#[test]
fn power_command_prints_component_ledger() {
    let out = enadapt(&["power", "mriq", "--meter", "oracle"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Per-component energy attribution"), "{text}");
    assert!(text.contains("host-cpu") && text.contains("accel"));
    assert!(text.contains("oracle"), "meter metadata shown: {text}");
    assert!(text.contains("dynamic-only"));
}

#[test]
fn unknown_meter_is_a_clean_error() {
    let out = enadapt(&["power", "mriq", "--meter", "wattmeter"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown meter"), "{err}");
}

#[test]
fn watt_capped_offload_respects_the_cap() {
    let out = enadapt(&[
        "offload", "mriq", "--dest", "gpu", "--watt-cap", "150", "--json",
        "--generations", "4", "--population", "6",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    let peak = j
        .get("production")
        .unwrap()
        .get("report")
        .unwrap()
        .get("peak_w")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(peak <= 150.0, "selected pattern peaks at {peak} W over the cap");
}

#[test]
fn exhaustive_pareto_prints_front_with_baseline_and_knee() {
    // The acceptance path: exhaust MRI-Q's 16-bit space on the default
    // FPGA destination and print the non-dominated front. It must contain
    // the all-CPU baseline point and mark the scalarization's knee (the
    // paper's offloaded point) — the knee marker only prints when the
    // chosen pattern is actually on the front.
    let out = enadapt(&["offload", "mriq", "--strategy", "exhaustive", "--pareto"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Pareto front"), "{text}");
    assert!(text.contains("(cpu-only)"), "front lacks the baseline: {text}");
    assert!(text.contains("<- knee"), "front lacks the knee marker: {text}");
    assert!(text.contains("search strategy: exhaustive"), "{text}");
}

#[test]
fn offload_mixed_dest_reports_a_letter_plan() {
    // The README quickstart path: per-loop destination genes with the
    // front printed. The report must carry the mixed strategy tag, a
    // letter plan, and mixed generated code.
    let out = enadapt(&[
        "offload", "mriq", "--mixed-dest", "--json",
        "--generations", "8", "--population", "10",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    let strategy = j.get("strategy").unwrap().as_str().unwrap().to_string();
    assert!(strategy.starts_with("mixed-dest("), "{strategy}");
    let pattern = j.get("pattern").unwrap().as_str().unwrap().to_string();
    assert!(
        pattern.chars().any(|c| matches!(c, 'G' | 'F' | 'M')),
        "mixed plan should render device letters: {pattern}"
    );
    assert_eq!(j.get("generated_kind").unwrap().as_str(), Some("mixed"));
    // `--pareto` renders the front rows as letter plans.
    let out = enadapt(&[
        "offload", "mriq", "--mixed-dest", "--pareto",
        "--generations", "8", "--population", "10",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Pareto front"), "{text}");
    assert!(text.contains("(cpu-only)"), "{text}");
    assert!(text.contains("mixed alphabet"), "{text}");
}

#[test]
fn anneal_strategy_runs_on_the_gpu() {
    let out = enadapt(&["offload", "mriq", "--dest", "gpu", "--strategy", "anneal", "--json"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(j.get("strategy").unwrap().as_str(), Some("anneal"));
    assert_eq!(j.get("device").unwrap().as_str(), Some("gpu"));
    assert!(!j.get("front").unwrap().as_arr().unwrap().is_empty());
}

#[test]
fn unknown_strategy_is_a_clean_error() {
    let out = enadapt(&["offload", "mriq", "--strategy", "tabu"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown strategy"), "{err}");
}

#[test]
fn codegen_manycore_emits_openmp() {
    let out = enadapt(&["codegen", "vecadd", "--dest", "manycore"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("#pragma omp parallel for") || text.contains("(cpu-only)") || !text.is_empty());
}

#[test]
fn fleet_json_completes_matrix_with_cache_hits() {
    let out = enadapt(&["fleet", "--json", "--population", "6", "--generations", "4"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    let jobs = j.get("jobs").unwrap().as_arr().unwrap();
    // Full matrix: 6 workloads x {gpu, fpga, manycore, mixed}.
    assert_eq!(jobs.len(), 24);
    assert!(jobs.iter().all(|job| job.get("ok").unwrap().as_bool() == Some(true)));
    let hits = j.get("cache").unwrap().get("hits").unwrap().as_f64().unwrap();
    assert!(hits > 0.0, "shared cache must deduplicate trials");
}

#[test]
fn blocks_command_lists_gemm_matmul() {
    let out = enadapt(&["blocks", "gemm"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("matmul"), "{text}");
    assert!(text.contains("cuBLAS"), "{text}");
    assert!(text.contains("IP core"), "{text}");
    assert!(text.contains("1 function block(s) detected"), "{text}");
}

#[test]
fn blocks_json_reports_zero_for_mriq() {
    let out = enadapt(&["blocks", "mriq", "--json"]);
    assert!(out.status.success());
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(j.get("n_blocks").unwrap().as_f64(), Some(0.0));
}

#[test]
fn offload_blocks_flag_beats_loop_only_on_gemm() {
    // The acceptance path at the CLI level: exhaust gemm's plan space on
    // the GPU with and without block substitution. The block-bearing
    // search must find a strictly lower-energy plan.
    let base = [
        "offload", "gemm", "--dest", "gpu", "--strategy", "exhaustive", "--json",
    ];
    let loop_only = enadapt(&base);
    assert!(loop_only.status.success(), "{}", String::from_utf8_lossy(&loop_only.stderr));
    let mut with_blocks_args = base.to_vec();
    with_blocks_args.push("--blocks");
    let with_blocks = enadapt(&with_blocks_args);
    assert!(with_blocks.status.success(), "{}", String::from_utf8_lossy(&with_blocks.stderr));
    let energy = |out: &std::process::Output| {
        enadapt::util::json::parse(&String::from_utf8_lossy(&out.stdout))
            .unwrap()
            .get("production")
            .unwrap()
            .get("energy_ws")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&with_blocks.stdout)).unwrap();
    assert_eq!(j.get("blocks_detected").unwrap().as_f64(), Some(1.0));
    assert_eq!(j.get("blocks_active").unwrap().as_f64(), Some(1.0));
    assert!(
        energy(&with_blocks) < energy(&loop_only),
        "block-substituted plan must beat the loop-only plan on W·s: {} vs {}",
        energy(&with_blocks),
        energy(&loop_only)
    );
}

#[test]
fn unknown_workload_lists_bundled_names() {
    let out = enadapt(&["analyze", "no-such-workload"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("mriq"), "{err}");
    assert!(err.contains("vecadd"), "{err}");
}

#[test]
fn workload_names_are_case_insensitive() {
    let out = enadapt(&["analyze", "MRIQ"]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("16 of 19"));
}

#[test]
fn report_prints_testbed() {
    let out = enadapt(&["report"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Arria10"));
    assert!(text.contains("16 candidates"));
}

#[test]
fn unknown_command_fails_cleanly() {
    let out = enadapt(&["bogus"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn bad_destination_fails_cleanly() {
    let out = enadapt(&["offload", "mriq", "--dest", "asic"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown destination"));
}

#[test]
fn sched_synthetic_run_prints_deterministic_ledger() {
    let args = [
        "sched", "--arrivals", "5", "--rate", "0.5", "--fleet-watt-cap", "500",
        "--seed", "7", "--population", "6", "--generations", "4", "--json",
    ];
    let a = enadapt(&args);
    assert!(a.status.success(), "{}", String::from_utf8_lossy(&a.stderr));
    let b = enadapt(&args);
    assert_eq!(a.stdout, b.stdout, "same seed ⇒ byte-identical ledger");
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&a.stdout)).unwrap();
    assert_eq!(j.get("jobs").unwrap().as_arr().unwrap().len(), 5);
    let energy = j.get("energy_ws").unwrap();
    assert!(energy.get("counterfactual_cpu").unwrap().as_f64().unwrap() > 0.0);
    assert!(energy.get("fleet_total").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn sched_trace_file_with_cap_event_renders_table() {
    let dir = std::env::temp_dir().join("enadapt_sched_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.txt");
    std::fs::write(&path, "0 mriq fpga\n5 cap 220\n10 mriq fpga 2.2\n").unwrap();
    let out = enadapt(&["sched", "--trace", path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("power-budget fleet"), "{text}");
    assert!(text.contains("all-CPU counterfactual"), "{text}");
    assert!(text.contains("re-adaptation"), "{text}");
    assert!(text.contains("fleet cap: 220 W"), "{text}");
}

#[test]
fn sched_rejects_bad_trace_and_bad_cap() {
    let out = enadapt(&["sched", "--trace", "/no/such/trace.txt"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot read trace"));
    let out = enadapt(&["sched", "--fleet-watt-cap", "lots"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("fleet-watt-cap"));
    // A zero arrival rate must be a clean config error, not a panic.
    let out = enadapt(&["sched", "--arrivals", "5", "--rate", "0"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--rate"));
}

/// The acceptance criterion at the CLI level: `--parallel-clusters` must
/// emit the byte-identical federation JSON (per-cluster ledgers and the
/// reconstructed cache counters included) as the serial path, per seed.
#[test]
fn sched_parallel_clusters_output_is_byte_identical_to_serial() {
    let base = [
        "sched", "--arrivals", "12", "--rate", "0.5", "--fleet-watt-cap", "800",
        "--clusters", "4", "--shard-seed", "1", "--seed", "7",
        "--population", "6", "--generations", "4", "--json",
    ];
    let serial = enadapt(&base);
    assert!(serial.status.success(), "{}", String::from_utf8_lossy(&serial.stderr));
    let mut parallel_args = base.to_vec();
    parallel_args.push("--parallel-clusters");
    let parallel = enadapt(&parallel_args);
    assert!(parallel.status.success(), "{}", String::from_utf8_lossy(&parallel.stderr));
    assert_eq!(
        serial.stdout, parallel.stdout,
        "--parallel-clusters must not change a byte of the report"
    );
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&serial.stdout)).unwrap();
    assert_eq!(j.get("clusters").unwrap().as_arr().unwrap().len(), 4);
    assert!(j.get("cache").unwrap().get("hits").unwrap().as_f64().unwrap() > 0.0);
}

/// `--cache-log` + `enadapt cache compact` round trip: a sched run
/// appends its measurements to the log, compaction folds them into a v3
/// snapshot, and a snapshot-only rerun re-measures nothing.
#[test]
fn sched_cache_log_compacts_into_a_snapshot() {
    let dir = std::env::temp_dir().join("enadapt_cli_cache_log_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join("measure.log");
    let snap = dir.join("cache.json");
    let base = [
        "sched", "--arrivals", "4", "--rate", "0.5", "--seed", "7",
        "--population", "6", "--generations", "4", "--json",
    ];

    let mut first_args = base.to_vec();
    first_args.extend(["--cache-log", log.to_str().unwrap()]);
    let first = enadapt(&first_args);
    assert!(first.status.success(), "{}", String::from_utf8_lossy(&first.stderr));
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&first.stdout)).unwrap();
    let cache = j.get("cache").unwrap();
    assert_eq!(cache.get("preloaded").unwrap().as_f64(), Some(0.0));
    let entries = cache.get("entries").unwrap().as_f64().unwrap();
    assert!(entries > 0.0);

    let compact = enadapt(&[
        "cache", "compact",
        "--log", log.to_str().unwrap(),
        "--snapshot", snap.to_str().unwrap(),
        "--json",
    ]);
    assert!(compact.status.success(), "{}", String::from_utf8_lossy(&compact.stderr));
    let cj = enadapt::util::json::parse(&String::from_utf8_lossy(&compact.stdout)).unwrap();
    assert_eq!(cj.get("entries").unwrap().as_f64(), Some(entries));
    assert_eq!(std::fs::metadata(&log).unwrap().len(), 0, "log truncated");

    let mut rerun_args = base.to_vec();
    rerun_args.extend(["--cache", snap.to_str().unwrap()]);
    let rerun = enadapt(&rerun_args);
    assert!(rerun.status.success(), "{}", String::from_utf8_lossy(&rerun.stderr));
    let rj = enadapt::util::json::parse(&String::from_utf8_lossy(&rerun.stdout)).unwrap();
    let rcache = rj.get("cache").unwrap();
    assert_eq!(rcache.get("preloaded").unwrap().as_f64(), Some(entries));
    assert_eq!(rcache.get("misses").unwrap().as_f64(), Some(0.0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cache_command_rejects_bad_usage() {
    let out = enadapt(&["cache", "defrag"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown cache action"));
    let out = enadapt(&["cache", "compact"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--log is required"));
}

#[test]
fn readme_quickstart_commands_exist_in_the_cli() {
    // README.md code blocks must not drift from the CLI: every
    // `enadapt <subcommand>` they show has to be a real subcommand.
    let readme_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md");
    let readme = std::fs::read_to_string(readme_path).expect("README.md exists at the repo root");
    let mut in_fence = false;
    let mut checked = 0;
    for line in readme.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if !in_fence {
            continue;
        }
        let mut rest = line;
        while let Some(pos) = rest.find("enadapt ") {
            rest = &rest[pos + "enadapt ".len()..];
            let word: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '-')
                .collect();
            // Skip flags and shell noise; bare lowercase words after the
            // binary name are subcommands.
            if !word.is_empty() && word.chars().all(|c| c.is_ascii_lowercase()) {
                assert!(
                    COMMANDS.contains(&word.as_str()),
                    "README shows 'enadapt {word}' but the CLI has no such command"
                );
                checked += 1;
            }
        }
    }
    assert!(checked >= 3, "README quickstart must show real commands (found {checked})");
    // The quickstart must cover the three fleet-relevant drivers.
    for cmd in ["offload", "fleet", "sched"] {
        assert!(
            readme.contains(&format!("enadapt {cmd}")),
            "README quickstart lacks `enadapt {cmd}`"
        );
    }
}

#[test]
fn file_source_works() {
    let dir = std::env::temp_dir().join("enadapt_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.c");
    std::fs::write(
        &path,
        "int main() { float a[8]; for (int i = 0; i < 8; i++) { a[i] = (float) i; } \
         printf(\"%f\", a[7]); return 0; }",
    )
    .unwrap();
    let out = enadapt(&["analyze", path.to_str().unwrap()]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("1 of 1"));
}

#[test]
fn sched_telemetry_outputs_and_obs_render() {
    let dir = std::env::temp_dir().join("enadapt_cli_obs_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.json");
    let metrics = dir.join("metrics.json");
    let series = dir.join("series.json");
    let out = enadapt(&[
        "sched",
        "--arrivals",
        "6",
        "--rate",
        "0.5",
        "--seed",
        "7",
        "--trace-out",
        trace.to_str().unwrap(),
        "--metrics-json",
        metrics.to_str().unwrap(),
        "--series-out",
        series.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Chrome trace: valid JSON with the traceEvents array (metadata +
    // virtual sched spans at minimum).
    let doc = enadapt::util::json::parse(&std::fs::read_to_string(&trace).unwrap())
        .expect("trace is valid JSON");
    assert!(doc.get("traceEvents").unwrap().as_arr().unwrap().len() > 2);

    // Metrics dump: the admission counter saw the arrivals.
    let m = enadapt::util::json::parse(&std::fs::read_to_string(&metrics).unwrap())
        .expect("metrics are valid JSON");
    let admitted = m
        .get("counters")
        .and_then(|c| c.get("sched.admitted"))
        .and_then(|v| v.as_f64())
        .expect("sched.admitted counter present");
    assert!(admitted > 0.0, "no admissions counted");

    // W·s series: non-empty deterministic power steps.
    let s = enadapt::util::json::parse(&std::fs::read_to_string(&series).unwrap())
        .expect("series is valid JSON");
    assert!(!s.get("power_steps").unwrap().as_arr().unwrap().is_empty());

    // `enadapt obs` renders the dump as tables.
    let out = enadapt(&["obs", metrics.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sched.admitted"), "{text}");
    assert!(text.contains("counter"), "{text}");
}

#[test]
fn cache_stats_renders_per_shard_occupancy() {
    let dir = std::env::temp_dir().join("enadapt_cli_cache_stats_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join("measure.log");
    let snapshot = dir.join("cache.json");
    // Produce a snapshot via a tiny logged sched run + compact.
    let out = enadapt(&[
        "sched",
        "--arrivals",
        "3",
        "--rate",
        "0.5",
        "--cache-log",
        log.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = enadapt(&[
        "cache",
        "compact",
        "--log",
        log.to_str().unwrap(),
        "--snapshot",
        snapshot.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = enadapt(&["cache", "stats", "--snapshot", snapshot.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("shard"), "{text}");
    assert!(text.contains("entries across 16 shards"), "{text}");
    // JSON form reconciles: per-shard entries sum to the total.
    let out = enadapt(&[
        "cache",
        "stats",
        "--snapshot",
        snapshot.to_str().unwrap(),
        "--json",
    ]);
    assert!(out.status.success());
    let j = enadapt::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    let total = j.get("entries").unwrap().as_f64().unwrap();
    let sum: f64 = j
        .get("shards")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.get("entries").unwrap().as_f64().unwrap())
        .sum();
    assert!(total > 0.0, "snapshot should hold measurements");
    assert_eq!(sum, total, "shard occupancy must sum to the total");
}
