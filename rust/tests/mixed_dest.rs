//! Mixed-destination differential + property suite (DESIGN.md §15).
//!
//! The two load-bearing guarantees of the per-gene destination
//! generalization, checked end-to-end:
//!
//! 1. **Single-destination runs are byte-identical to the classic flow**:
//!    with `mixed_dest` off the code path is untouched, and a singleton
//!    alphabet folds onto exactly the classic per-device flow, so the
//!    whole JobReport JSON matches byte for byte per seed.
//! 2. **The widened search is sound**: an exhaustive 4^len enumeration of
//!    a small plan space is ground truth — no enumerated plan dominates
//!    the GA front, and the all-CPU baseline is always a front point.
//!
//! Plus `util::prop` property tests over the new codecs: plan
//! encode/parse/render round trips, transfer-edge charging symmetry, and
//! the measurement-cache v3 → v4 schema migration.

use enadapt::canalyze::analyze_source;
use enadapt::coordinator::{report, run_job, Destination, JobConfig};
use enadapt::devices::{DeviceKind, TransferMode};
use enadapt::funcblock::{dests_from_wide, wide_from_dests, OffloadPlan};
use enadapt::offload::{fpga_flow, gpu_flow, mixed_dest, FpgaFlowConfig, GpuFlowConfig, MixedDestSpec};
use enadapt::search::{dominates, GaConfig, SearchStrategy};
use enadapt::util::json::Json;
use enadapt::util::measure_cache::{MeasureCache, MeasureKey};
use enadapt::util::prop::{run as prop_run, Gen};
use enadapt::verifier::{AppModel, VerifEnv, VerifEnvConfig};
use enadapt::workloads;

const DEVICES: [DeviceKind; 4] = [
    DeviceKind::Cpu,
    DeviceKind::Gpu,
    DeviceKind::Fpga,
    DeviceKind::ManyCore,
];

fn quick_job(seed: u64, device: DeviceKind) -> JobConfig {
    let mut cfg = JobConfig::default();
    cfg.seed = seed;
    cfg.destination = Destination::Device(device);
    cfg.ga_flow.seed = seed;
    cfg.ga_flow.ga.population = 6;
    cfg.ga_flow.ga.generations = 4;
    cfg
}

/// With `mixed_dest` unset the classic flow runs untouched; forcing a
/// **singleton** alphabet must fold onto that exact flow — every
/// registered workload's JobReport JSON stays byte-identical per seed.
#[test]
fn singleton_mixed_dest_job_json_is_byte_identical_per_seed() {
    let mut compared = 0;
    for &(name, src) in workloads::ALL {
        let seeds: &[u64] = if name == "mriq" { &[7, 42] } else { &[42] };
        for &seed in seeds {
            for device in [DeviceKind::Gpu, DeviceKind::ManyCore] {
                let base_cfg = quick_job(seed, device);
                let mut forced_cfg = quick_job(seed, device);
                forced_cfg.mixed_dest = Some(MixedDestSpec {
                    alphabet: vec![device],
                });
                let file = format!("{name}.c");
                let base = run_job(&file, src, &base_cfg).unwrap();
                let forced = run_job(&file, src, &forced_cfg).unwrap();
                assert_eq!(
                    report::job_json(&base).to_string_pretty(),
                    report::job_json(&forced).to_string_pretty(),
                    "{name} seed {seed} on {device:?}: singleton alphabet must fold \
                     onto the classic flow byte for byte"
                );
                compared += 1;
            }
        }
    }
    assert!(compared >= workloads::ALL.len() * 2, "covered every workload");

    // The FPGA narrowing funnel folds identically.
    let base = run_job("mriq.c", workloads::MRIQ_C, &quick_job(42, DeviceKind::Fpga)).unwrap();
    let mut forced_cfg = quick_job(42, DeviceKind::Fpga);
    forced_cfg.mixed_dest = Some(MixedDestSpec {
        alphabet: vec![DeviceKind::Fpga],
    });
    let forced = run_job("mriq.c", workloads::MRIQ_C, &forced_cfg).unwrap();
    assert_eq!(
        report::job_json(&base).to_string_pretty(),
        report::job_json(&forced).to_string_pretty()
    );
}

/// Three independent top-level loops: init, map, reduce. Small enough for
/// the exhaustive 4^3 ground truth, real enough that offloading matters.
const TRI_C: &str = "int main() {
  float a[512]; float b[512]; float s = 0.0f;
  for (int i = 0; i < 512; i++) { a[i] = (float) i; }
  for (int j = 0; j < 512; j++) { b[j] = a[j] * 2.0f + 1.0f; }
  for (int k = 0; k < 512; k++) { s += b[k] * b[k]; }
  printf(\"%f\", s);
  return 0;
}
";

fn tri_setup(seed: u64) -> (AppModel, VerifEnv) {
    let an = analyze_source("tri.c", TRI_C).unwrap();
    let cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &cfg.cpu, 14.0).unwrap();
    (app, cfg.build(seed))
}

/// Exhaustively enumerate the whole 4^len mixed plan space of a small
/// app as ground truth: the GA front must contain no point any
/// enumerated plan strictly dominates, and the all-CPU baseline must sit
/// on both fronts.
#[test]
fn exhaustive_ground_truth_confirms_the_ga_front() {
    let (app, env) = tri_setup(11);
    let n = app.genome_len();
    assert!(
        (1..=6).contains(&n),
        "ground-truth space must stay enumerable, got {n} genes"
    );
    let spec = MixedDestSpec::default();
    let width = spec.genome_width(n);

    let exhaustive_cfg = GpuFlowConfig {
        strategy: SearchStrategy::Exhaustive { max_bits: 16 },
        ..GpuFlowConfig::default()
    };
    let truth = mixed_dest::run(&app, &env, &exhaustive_cfg, &spec).unwrap();
    assert_eq!(
        truth.trials,
        1usize << width,
        "exhaustive mixed search must enumerate all 4^{n} plans"
    );

    let ga_cfg = GpuFlowConfig {
        ga: GaConfig {
            population: 24,
            generations: 20,
            ..GaConfig::default()
        },
        ..GpuFlowConfig::default()
    };
    let env2 = VerifEnvConfig::r740_pac().build(11);
    let ga = mixed_dest::run(&app, &env2, &ga_cfg, &spec).unwrap();

    // Ground-truth check: nothing in the enumerated front dominates any
    // GA front point (any dominating plan is itself dominated by a
    // ground-truth front point, so checking the front suffices).
    for g in &ga.search.front.points {
        for t in &truth.search.front.points {
            assert!(
                !dominates(&t.objectives, &g.objectives),
                "enumerated plan {} dominates GA front point {}",
                mixed_dest::plan_of_genome(&app, &spec, &t.genome),
                mixed_dest::plan_of_genome(&app, &spec, &g.genome),
            );
        }
    }
    // The all-CPU baseline (strictly lowest peak draw) stays on both
    // fronts.
    for (label, front) in [("exhaustive", &truth.search.front), ("ga", &ga.search.front)] {
        assert!(
            front.points.iter().any(|s| s.genome.ones() == 0),
            "{label} front lost the all-CPU baseline"
        );
    }
}

/// The acceptance criterion through the public API: on MRI-Q the mixed
/// front must contain a plan with strictly lower W·s than the best plan
/// any single-destination flow finds.
#[test]
fn mixed_front_beats_the_best_single_destination_plan_on_energy() {
    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();
    let cfg = GpuFlowConfig {
        ga: GaConfig {
            population: 12,
            generations: 10,
            ..GaConfig::default()
        },
        ..GpuFlowConfig::default()
    };

    let mut single_best = f64::INFINITY;
    for d in [DeviceKind::ManyCore, DeviceKind::Gpu] {
        let env = VerifEnvConfig::r740_pac().build(99);
        let out = gpu_flow::run_on(&app, &env, &cfg, d).unwrap();
        single_best = single_best.min(out.best.measurement.energy_ws);
    }
    let env = VerifEnvConfig::r740_pac().build(99);
    let fpga = fpga_flow::run(&app, &env, &FpgaFlowConfig::default()).unwrap();
    single_best = single_best.min(fpga.best.measurement.energy_ws);

    let env = VerifEnvConfig::r740_pac().build(99);
    let mixed = mixed_dest::run(&app, &env, &cfg, &MixedDestSpec::default()).unwrap();
    let mixed_best = mixed
        .search
        .front
        .points
        .iter()
        .map(|s| s.objectives.energy_ws)
        .fold(f64::INFINITY, f64::min);
    assert!(
        mixed_best < single_best,
        "mixed front min {mixed_best} W·s must strictly beat the best \
         single-destination plan's {single_best} W·s"
    );
}

/// Watt-capped mixed jobs keep the classic hard guarantee end to end.
#[test]
fn watt_capped_mixed_job_respects_the_cap() {
    let mut cfg = quick_job(42, DeviceKind::Gpu);
    cfg.mixed_dest = Some(MixedDestSpec::default());
    cfg.map_fitness(|f| f.with_watt_cap(150.0));
    let r = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
    assert!(
        r.production.report.peak_w <= 150.0,
        "capped mixed job peaks at {} W",
        r.production.report.peak_w
    );
}

// ---- property tests (util::prop) ----------------------------------------

/// Random destination vectors round-trip through `OffloadPlan`
/// encode/parse/render, and through the widened-genome codec.
#[test]
fn prop_dest_vectors_round_trip_through_plan_and_codec() {
    prop_run("mixed plan round trip", 128, |g: &mut Gen| {
        let n_loops = g.usize_range(1, 6);
        let n_blocks = g.usize_range(0, 3);
        let dests: Vec<DeviceKind> = (0..n_loops + n_blocks)
            .map(|_| *g.pick(&DEVICES))
            .collect();
        let plan = OffloadPlan::mixed(n_loops, dests.clone());
        // Derived selection bits agree with the destinations.
        for (i, &d) in dests.iter().enumerate() {
            assert_eq!(plan.bits[i], d != DeviceKind::Cpu);
        }
        // Render -> parse is the identity.
        let rendered = plan.to_string();
        let parsed = OffloadPlan::parse(&rendered).unwrap();
        assert_eq!(parsed, plan, "parse(render) of '{rendered}'");
        // Widened-genome codec round trip.
        assert_eq!(dests_from_wide(&wide_from_dests(&dests)), dests);
    });
}

/// Transfer-edge charging is symmetric in its endpoints and zero when
/// adjacent units share a destination.
#[test]
fn prop_transfer_edges_are_symmetric_and_zero_on_same_device() {
    prop_run("hop symmetry", 256, |g: &mut Gen| {
        let env = VerifEnvConfig::r740_pac().build(g.rng().next_u64());
        let a = *g.pick(&DEVICES);
        let b = *g.pick(&DEVICES);
        let payload = g.f64_pos(1.0, 1e9);
        let ab = env.hop_cost_s(a, b, payload);
        let ba = env.hop_cost_s(b, a, payload);
        assert_eq!(ab, ba, "hop {a:?}->{b:?} vs {b:?}->{a:?} at {payload} B");
        assert_eq!(env.hop_cost_s(a, a, payload), 0.0, "same-device hop");
        if a != b && a != DeviceKind::Cpu && b != DeviceKind::Cpu {
            assert!(ab > 0.0, "cross-accelerator hop {a:?}->{b:?} must cost time");
        }
    });
}

fn cache_key(g: &mut Gen, dests: Vec<DeviceKind>) -> MeasureKey {
    let len = dests.len().max(g.usize_range(1, 8));
    let pattern = match dests.is_empty() {
        true => g.bits(len),
        false => dests.iter().map(|&d| d != DeviceKind::Cpu).collect(),
    };
    MeasureKey {
        app_hash: g.rng().next_u64(),
        pattern,
        plan: g.rng().next_u64(),
        device: if dests.is_empty() {
            *g.pick(&[DeviceKind::Gpu, DeviceKind::Fpga, DeviceKind::ManyCore])
        } else {
            DeviceKind::Cpu
        },
        xfer: if g.bool() {
            TransferMode::Batched
        } else {
            TransferMode::PerEntry
        },
        env_fingerprint: g.rng().next_u64(),
        dests,
    }
}

/// Cache schema migration: v4 snapshots round-trip (mixed keys
/// included); single-destination entries are v3-shaped, so a v3 file
/// loads under v4 and keeps hitting for single-destination plans.
#[test]
fn prop_cache_v3_to_v4_migration_round_trips() {
    let an = analyze_source("vecadd.c", workloads::VECADD_C).unwrap();
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();

    prop_run("cache v3/v4 migration", 24, move |g: &mut Gen| {
        // One real measurement as the payload for every synthetic key.
        let m = VerifEnvConfig::r740_pac()
            .build(5)
            .measure_cpu_only(&app);
        let cache = MeasureCache::new();
        let singles: Vec<MeasureKey> = (0..g.usize_range(1, 5))
            .map(|_| cache_key(g, Vec::new()))
            .collect();
        let mixed: Vec<MeasureKey> = (0..g.usize_range(1, 3))
            .map(|_| {
                let dests = (0..g.usize_range(1, 6)).map(|_| *g.pick(&DEVICES)).collect();
                cache_key(g, dests)
            })
            .collect();
        for k in singles.iter().chain(&mixed) {
            cache.get_or_measure(k.clone(), || m.clone());
        }

        // v4 round trip carries every entry, mixed keys included.
        let v4 = MeasureCache::from_json(&cache.to_json()).unwrap();
        assert_eq!(v4.len(), cache.len());
        for k in singles.iter().chain(&mixed) {
            let (_, hit) = v4.get_or_measure(k.clone(), || unreachable!("must hit"));
            assert!(hit, "v4 round trip lost {k:?}");
        }

        // The same entries under a v3 header load, and the
        // single-destination keys keep hitting (v3 entries *are* the
        // empty-dests key shape).
        let single_cache = MeasureCache::new();
        for k in &singles {
            single_cache.get_or_measure(k.clone(), || m.clone());
        }
        let entries = single_cache.to_json().get("entries").unwrap().clone();
        let v3_json = Json::obj(vec![("version", Json::num(3.0)), ("entries", entries)]);
        let v3 = MeasureCache::from_json(&v3_json).unwrap();
        assert_eq!(v3.len(), singles.len());
        for k in &singles {
            let (_, hit) = v3.get_or_measure(k.clone(), || unreachable!("must hit"));
            assert!(hit, "v3 entry must hit under v4 for {k:?}");
        }
    });
}

/// Malformed v4 `dests` fields are strict load errors, not silent
/// single-destination fallbacks.
#[test]
fn malformed_v4_dests_entries_are_strict_errors() {
    let an = analyze_source("vecadd.c", workloads::VECADD_C).unwrap();
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();
    let m = env_cfg.build(5).measure_cpu_only(&app);

    let cache = MeasureCache::new();
    let key = MeasureKey {
        app_hash: 7,
        pattern: vec![true, false, true],
        plan: 0,
        device: DeviceKind::Cpu,
        xfer: TransferMode::Batched,
        env_fingerprint: 9,
        dests: vec![DeviceKind::Gpu, DeviceKind::Cpu, DeviceKind::ManyCore],
    };
    cache.get_or_measure(key, || m);
    let text = cache.to_json().to_string_compact();
    assert!(text.contains("\"G-M\""), "serialized dests letters: {text}");

    let bad_letter = enadapt::util::json::parse(&text.replace("\"G-M\"", "\"G-Q\"")).unwrap();
    let err = MeasureCache::from_json(&bad_letter).unwrap_err();
    assert!(
        err.to_string().contains("bad dests letter"),
        "unexpected error: {err}"
    );

    let bad_len = enadapt::util::json::parse(&text.replace("\"G-M\"", "\"G-MM\"")).unwrap();
    let err = MeasureCache::from_json(&bad_len).unwrap_err();
    assert!(
        err.to_string().contains("does not match pattern length"),
        "unexpected error: {err}"
    );
}
