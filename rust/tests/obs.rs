//! Telemetry contracts (`enadapt::obs`): turning spans / metrics /
//! series on must not change a single byte of any report; the exported
//! trace is valid Chrome trace-event JSON with balanced wall B/E pairs;
//! the W·s series is bit-identical per seed; and the metrics registry
//! reconciles *exactly* (equality, not approximation) with the cache
//! hit/miss ledger and the sched admission/drop ledger.
//!
//! Obs state is process-global (one registry, one span buffer, one
//! series), so every test serializes on `LOCK` and starts from
//! `obs::reset()`.

use enadapt::coordinator::sched::{run_sched, run_sched_with_cache};
use enadapt::coordinator::{
    run_federated, run_job, ArrivalTrace, FederationConfig, JobConfig, SchedConfig,
    SyntheticTraceConfig,
};
use enadapt::devices::NodeSpec;
use enadapt::obs;
use enadapt::offload::GpuFlowConfig;
use enadapt::search::GaConfig;
use enadapt::util::measure_cache::MeasureCache;
use enadapt::workloads;
use std::sync::{Arc, Mutex};

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Small-search template so GA destinations stay fast in tests.
fn quick_template() -> JobConfig {
    JobConfig {
        ga_flow: GpuFlowConfig {
            ga: GaConfig {
                population: 6,
                generations: 4,
                ..Default::default()
            },
            parallel_trials: false,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn two_node_cluster() -> Vec<NodeSpec> {
    vec![NodeSpec::r740_pac("node0"), NodeSpec::r740_pac("node1")]
}

fn sched_cfg() -> SchedConfig {
    SchedConfig {
        template: quick_template(),
        nodes: two_node_cluster(),
        fleet_watt_cap: Some(500.0),
        ..Default::default()
    }
}

/// The drift/cap trace from `tests/sched.rs`: one cap event, one
/// re-search, one drop — exercises every sched telemetry hook.
fn cap_event_trace() -> ArrivalTrace {
    ArrivalTrace::parse(
        "0  mriq fpga 1.0\n\
         5  cap 220\n\
         10 mriq fpga 2.2\n\
         20 mriq fpga 2.2\n\
         30 mriq fpga 2.2\n",
    )
    .unwrap()
}

/// Telemetry is purely observational: with every pillar enabled the
/// SchedReport must serialize byte-identically to the telemetry-off
/// run, on both a standard synthetic trace and a cap-event trace.
#[test]
fn full_telemetry_leaves_sched_reports_byte_identical() {
    let _g = lock();
    let standard = ArrivalTrace::poisson(&SyntheticTraceConfig::standard(6, 0.5, 9));
    let traces = [
        (&standard, sched_cfg()),
        (
            &cap_event_trace(),
            SchedConfig {
                nodes: two_node_cluster(),
                ..Default::default()
            },
        ),
    ];
    for (trace, cfg) in traces {
        obs::reset();
        let off = run_sched(trace, &cfg).unwrap().to_json().to_string_compact();
        obs::reset();
        obs::enable(obs::ALL);
        let on = run_sched(trace, &cfg).unwrap().to_json().to_string_compact();
        assert!(obs::span::len() > 0, "spans were actually recorded");
        assert!(
            !obs::series::power_steps().is_empty(),
            "series rows were actually recorded"
        );
        obs::reset();
        assert_eq!(off, on, "telemetry changed the report");
    }
}

/// Same contract across the federation, including the parallel path:
/// concurrent clusters appending to the shared span buffer / series
/// must not perturb the merged report.
#[test]
fn full_telemetry_leaves_federated_report_byte_identical() {
    let _g = lock();
    let trace = ArrivalTrace::poisson(&SyntheticTraceConfig::standard(12, 0.5, 9));
    let cfg = FederationConfig {
        base: sched_cfg(),
        clusters: 2,
        shard_seed: 1,
        parallel: true,
        ..Default::default()
    };
    obs::reset();
    let off = run_federated(&trace, &cfg).unwrap().to_json().to_string_compact();
    obs::reset();
    obs::enable(obs::ALL);
    let on = run_federated(&trace, &cfg).unwrap().to_json().to_string_compact();
    obs::reset();
    assert_eq!(off, on, "telemetry changed the federated report");
}

/// The single-job pipeline (Steps 1–7) is likewise untouched: pattern,
/// trial count, and the full production measurement agree bit for bit
/// with spans + metrics on.
#[test]
fn full_telemetry_leaves_job_report_identical() {
    let _g = lock();
    let (name, src) = workloads::resolve("mriq").unwrap();
    let cfg = quick_template();
    obs::reset();
    let off = run_job(&format!("{name}.c"), src, &cfg).unwrap();
    obs::reset();
    obs::enable(obs::ALL);
    let on = run_job(&format!("{name}.c"), src, &cfg).unwrap();
    obs::reset();
    assert_eq!(
        off.production.to_json_full().to_string_compact(),
        on.production.to_json_full().to_string_compact(),
        "production measurement diverged"
    );
    assert_eq!(off.trials, on.trials);
    assert_eq!(off.baseline.energy_ws, on.baseline.energy_ws);
    assert_eq!(off.best.value.to_bits(), on.best.value.to_bits());
}

/// The exported Chrome trace from a real sched run parses as JSON and
/// is structurally valid: wall B/E pairs balance, every virtual span is
/// a complete (`X`) event with a duration, and the W·s counter track is
/// present with its three components.
#[test]
fn sched_trace_exports_valid_chrome_json() {
    let _g = lock();
    obs::reset();
    obs::enable(obs::SPANS | obs::SERIES);
    run_sched(&cap_event_trace(), &sched_cfg()).unwrap();
    let doc = obs::chrome::export().to_string_compact();
    obs::reset();
    let parsed = enadapt::util::json::parse(&doc).expect("trace is valid JSON");
    let evs = parsed
        .get("traceEvents")
        .and_then(|t| t.as_arr())
        .expect("traceEvents array");
    let mut begins = 0u64;
    let mut ends = 0u64;
    let mut completes = 0u64;
    let mut counters = 0u64;
    for e in evs {
        match e.get("ph").and_then(|p| p.as_str()).expect("every event has ph") {
            "B" => begins += 1,
            "E" => ends += 1,
            "X" => {
                completes += 1;
                assert!(e.get("dur").is_some(), "X events carry a duration");
                assert_eq!(e.get("pid").and_then(|p| p.as_f64()), Some(2.0));
            }
            "C" => {
                counters += 1;
                let args = e.get("args").expect("counter args");
                for k in ["committed_w", "dynamic_w", "idle_w"] {
                    assert!(args.get(k).is_some(), "counter lacks {k}");
                }
            }
            "M" => {}
            ph => panic!("unexpected phase {ph}"),
        }
    }
    assert_eq!(begins, ends, "wall spans must balance");
    assert!(begins > 0, "pipeline/search/verifier spans recorded");
    assert!(completes > 0, "admitted jobs render as virtual spans");
    assert!(counters > 0, "the power track is present");
}

/// The W·s series is a pure function of (trace, config, seed): two
/// identical runs export byte-identical JSON, and accelerator idle
/// folds appear when the cluster actually carries a per-slot idle draw
/// (gpu_box nodes).
#[test]
fn power_series_is_bit_identical_per_seed() {
    let _g = lock();
    let trace = ArrivalTrace::parse("0 vecadd gpu\n40 vecadd gpu\n").unwrap();
    let cfg = SchedConfig {
        template: quick_template(),
        nodes: vec![NodeSpec::gpu_box("g0")],
        idle_policy: enadapt::power::IdlePolicy::gate_after(5.0),
        ..Default::default()
    };
    obs::reset();
    obs::enable(obs::SERIES);
    run_sched(&trace, &cfg).unwrap();
    let first = obs::series::to_json().to_string_compact();
    obs::series::reset();
    run_sched(&trace, &cfg).unwrap();
    let second = obs::series::to_json().to_string_compact();
    let steps = obs::series::power_steps();
    let folds = obs::series::idle_folds();
    obs::reset();
    assert_eq!(first, second, "series must be bit-identical per seed");
    // 2 admissions + 2 completions on one node.
    assert_eq!(steps.len(), 4);
    assert!(steps.iter().all(|s| s.node == 0));
    assert!(
        steps.iter().any(|s| s.committed_w > 0.0),
        "admissions commit power"
    );
    assert!(!folds.is_empty(), "gpu_box idle slots fold into the series");
    assert!(folds.iter().all(|f| f.idle_w > 0.0));
}

/// Metrics reconcile exactly with the ledgers the simulation itself
/// reports: admitted/dropped counters equal the SchedReport's, cap
/// events are counted, and the cache hit/miss counters equal the
/// MeasureCache's own atomic ledger (the PR 8 relaxed-is-exact
/// argument, asserted end to end).
#[test]
fn metrics_reconcile_with_cache_and_sched_ledgers() {
    let _g = lock();
    obs::reset();
    obs::enable(obs::METRICS);
    let cache = Arc::new(MeasureCache::new());
    let cfg = SchedConfig {
        nodes: two_node_cluster(),
        ..Default::default()
    };
    let report = run_sched_with_cache(&cap_event_trace(), &cfg, Arc::clone(&cache)).unwrap();
    let admitted = obs::metrics::counter_value("sched.admitted");
    let dropped = obs::metrics::counter_value("sched.dropped");
    let cap_events = obs::metrics::counter_value("sched.cap_events");
    let hits = obs::metrics::counter_value("cache.hits");
    let misses = obs::metrics::counter_value("cache.misses");
    let hit_rate = obs::metrics::gauge_value("cache.hit_rate");
    let trials = obs::metrics::counter_value("verifier.trials");
    let generations = obs::metrics::counter_value("search.generations");
    let queued = obs::metrics::counter_value("sched.queued");
    let queue_depth = obs::metrics::histogram("sched.queue_depth");
    obs::reset();

    assert_eq!(admitted, report.admitted as u64, "admission counter drifted");
    assert_eq!(dropped, report.dropped as u64, "drop counter drifted");
    assert!(report.dropped > 0, "the 220 W cap must drop something");
    assert_eq!(cap_events, 1, "one cap event in the trace");
    assert_eq!(hits, cache.hits(), "cache hit counter drifted");
    assert_eq!(misses, cache.misses(), "cache miss counter drifted");
    assert!(misses > 0, "fresh cache must miss");
    assert_eq!(hit_rate, Some(cache.hit_rate()), "hit-rate gauge drifted");
    assert!(trials > 0, "verifier trials counted");
    assert!(generations > 0, "search generations counted");
    // Every queueing decision records one depth sample.
    match queue_depth {
        Some(q) => assert_eq!(q.count(), queued, "queue histogram drifted"),
        None => assert_eq!(queued, 0, "queued jobs without a depth sample"),
    }
}

/// The per-shard cache counters sum to the aggregate ledger, and the
/// occupancy gauges published at report time match `shard_stats`.
#[test]
fn shard_metrics_sum_to_the_aggregate_cache_ledger() {
    let _g = lock();
    obs::reset();
    obs::enable(obs::METRICS);
    let cache = Arc::new(MeasureCache::new());
    let cfg = SchedConfig {
        nodes: two_node_cluster(),
        ..Default::default()
    };
    run_sched_with_cache(&cap_event_trace(), &cfg, Arc::clone(&cache)).unwrap();
    let mut shard_hits = 0u64;
    let mut shard_misses = 0u64;
    let mut gauge_entries = 0.0f64;
    for i in 0..16 {
        shard_hits += obs::metrics::counter_value(&format!("cache.shard{i:02}.hits"));
        shard_misses += obs::metrics::counter_value(&format!("cache.shard{i:02}.misses"));
        gauge_entries += obs::metrics::gauge_value(&format!("cache.shard{i:02}.entries"))
            .expect("occupancy gauge published at report time");
    }
    let entries_gauge = obs::metrics::gauge_value("cache.entries");
    obs::reset();
    // Memo-layer `note_hits` credits land in the aggregate only, so the
    // shard sum is a lower bound on hits and exact on misses.
    assert!(shard_hits <= cache.hits());
    assert_eq!(shard_misses, cache.misses(), "per-shard misses drifted");
    let stats = cache.shard_stats();
    assert_eq!(shard_hits, stats.iter().map(|s| s.hits).sum::<u64>());
    assert_eq!(gauge_entries, cache.len() as f64, "occupancy gauges drifted");
    assert_eq!(entries_gauge, Some(cache.len() as f64));
}
