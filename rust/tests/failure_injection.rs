//! Failure-injection tests: what the verification environment and flows do
//! when things go wrong — oversized FPGA kernels, trials past the timeout,
//! missing profiles/artifacts, degenerate search spaces.

use enadapt::canalyze::analyze_source;
use enadapt::devices::{Accelerator, DeviceKind, FpgaModel, NestWork, TransferMode};
use enadapt::search::FitnessSpec;
use enadapt::offload::{fpga_flow, FpgaFlowConfig};
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

/// Build a program whose hot loop body contains ~200 special-function
/// cores — no Arria10 pipeline fits that (DSP budget ≈ 1,160 usable, each
/// sin/cos core ≈ 8 DSPs + 4,500 LUTs), so the precompile stage must
/// reject it and the flow must fall back gracefully.
fn monster_source() -> String {
    let mut terms: Vec<String> = Vec::new();
    for k in 0..100 {
        terms.push(format!("sinf(b[i] * {k}.0f)"));
        terms.push(format!("cosf(a[i] * {k}.5f)"));
    }
    format!(
        "#define N 64\n\
         int main() {{\n\
           float a[N];\n\
           float b[N];\n\
           for (int i = 0; i < N; i++) {{ a[i] = (float) i; b[i] = 1.0f; }}\n\
           for (int i = 0; i < N; i++) {{\n\
             a[i] = {};\n\
           }}\n\
           float s = 0.0f;\n\
           for (int i = 0; i < N; i++) {{ s += a[i]; }}\n\
           printf(\"%f\", s);\n\
           return 0;\n\
         }}\n",
        terms.join(" + ")
    )
}

#[test]
fn oversized_kernel_is_rejected_at_precompile() {
    let monster_src = monster_source();
    let an = analyze_source("monster.c", &monster_src).unwrap();
    let cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &cfg.cpu, 5.0).unwrap();
    // The trig-monster loop does not fit the Arria10 (≥40 special cores).
    let monster = app
        .loops
        .iter()
        .filter(|l| l.parallelizable)
        .max_by_key(|l| l.work.census.fspecial)
        .unwrap();
    assert!(
        cfg.fpga.supports(&monster.work).is_err(),
        "monster body must be rejected: census {:?}",
        monster.work.census
    );
    // The flow still completes (falls back to other candidates/baseline).
    let env = VerifEnvConfig::r740_pac().build(1);
    let out = fpga_flow::run(&app, &env, &FpgaFlowConfig::default()).unwrap();
    assert!(out.funnel.after_fit < out.funnel.after_trips || out.funnel.after_fit > 0);
    assert!(!out
        .best
        .pattern
        .offloaded_ids()
        .contains(&monster.id));
}

#[test]
fn unsupported_pattern_measures_as_failed_timeout() {
    let monster_src = monster_source();
    let an = analyze_source("monster.c", &monster_src).unwrap();
    let cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &cfg.cpu, 5.0).unwrap();
    let env = VerifEnvConfig::r740_pac().build(2);
    let monster = app
        .loops
        .iter()
        .filter(|l| l.parallelizable)
        .max_by_key(|l| l.work.census.fspecial)
        .unwrap()
        .id;
    let pos = app.candidates.iter().position(|&c| c == monster).unwrap();
    let mut bits = vec![false; app.genome_len()];
    bits[pos] = true;
    let m = env.measure(&app, &bits, DeviceKind::Fpga, TransferMode::Batched);
    assert!(m.timed_out, "unsupported kernel behaves as a failed trial");
    assert!(m.failure.is_some());
    // Its evaluation value uses the 1000 s substitution and is therefore
    // worse than the plain CPU run.
    let f = FitnessSpec::paper();
    let cpu = env.measure_cpu_only(&app);
    assert!(
        f.value(m.time_s, m.mean_w, m.timed_out)
            < f.value(cpu.time_s, cpu.mean_w, cpu.timed_out)
    );
}

#[test]
fn trials_past_the_timeout_are_flagged() {
    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let mut cfg = VerifEnvConfig::r740_pac();
    cfg.timeout_s = 1.0; // absurd 1 s timeout: the 14 s CPU run must trip it
    let app = AppModel::from_analysis(&an, &cfg.cpu, 14.0).unwrap();
    let env = cfg.build(3);
    let m = env.measure_cpu_only(&app);
    assert!(m.timed_out);
    let f = FitnessSpec::paper();
    let v = f.value(m.time_s, m.mean_w, m.timed_out);
    assert!((v - (1000.0 * m.mean_w).powf(-0.5)).abs() < 1e-12);
}

#[test]
fn per_entry_inner_loop_can_time_out_entirely() {
    // Offloading the MRI-Q inner k-loop per-entry at full scale launches
    // tens of thousands of kernels; with a tight timeout this times out —
    // the exact failure mode the paper's measurement-driven search learns
    // to avoid.
    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let mut cfg = VerifEnvConfig::r740_pac();
    cfg.timeout_s = 5.0;
    let app = AppModel::from_analysis(&an, &cfg.cpu, 14.0).unwrap();
    let env = cfg.build(4);
    let outer = app
        .loops
        .iter()
        .max_by(|a, b| a.cpu_time_s.partial_cmp(&b.cpu_time_s).unwrap())
        .unwrap()
        .id;
    let inner = app.loops.iter().find(|l| l.parent == Some(outer)).unwrap().id;
    let pos = app.candidates.iter().position(|&c| c == inner).unwrap();
    let mut bits = vec![false; app.genome_len()];
    bits[pos] = true;
    let naive = env.measure(&app, &bits, DeviceKind::Gpu, TransferMode::PerEntry);
    assert!(naive.timed_out, "per-entry inner offload must blow the 5 s budget (took {:.2} s)", naive.time_s);
}

#[test]
fn profileless_source_fails_model_building_cleanly() {
    let an = analyze_source(
        "lib.c",
        "void f(float *a, int n) { for (int i = 0; i < n; i++) { a[i] = 0.0f; } }",
    )
    .unwrap();
    let cfg = VerifEnvConfig::r740_pac();
    let err = AppModel::from_analysis(&an, &cfg.cpu, 1.0).unwrap_err();
    assert!(err.to_string().contains("no dynamic profile"));
}

#[test]
fn missing_artifacts_dir_reports_make_hint() {
    let err = enadapt::runtime::load_artifacts(std::path::Path::new("/no/such/dir")).unwrap_err();
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn fpga_model_rejection_reason_is_actionable() {
    let fpga = FpgaModel::arria10();
    let w = NestWork {
        flops: 1e9,
        bytes: 1e8,
        transfer_bytes: 1e6,
        entries: 1.0,
        trips: 1e6,
        census: enadapt::canalyze::OpCensus {
            fadd: 100,
            fmul: 500,
            fdiv: 20,
            fspecial: 300,
            iops: 50,
            loads: 40,
            stores: 10,
            calls: 0,
        },
    };
    let reason = fpga.supports(&w).unwrap_err();
    assert!(reason.contains("utilization"), "{reason}");
    assert!(reason.contains("Arria10"), "{reason}");
}
