//! Function-block offloading integration tests: detection ground truth
//! (exact spans, zero false positives on MRI-Q), the loop-only
//! bit-identity guarantee, the Pareto acceptance criterion on gemm, and
//! the block-aware scheduler's deterministic ledger.

use enadapt::canalyze::analyze_source;
use enadapt::coordinator::sched::{run_sched, SchedOutcome};
use enadapt::coordinator::{ArrivalTrace, JobConfig, SchedConfig};
use enadapt::devices::{DeviceKind, TransferMode};
use enadapt::funcblock::{detect, BlockDb, BlockKind};
use enadapt::offload::{gpu_flow, GpuFlowConfig};
use enadapt::search::SearchStrategy;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn blocks_app(name: &str, src: &str, target_s: f64) -> AppModel {
    let an = analyze_source(name, src).unwrap();
    let cfg = VerifEnvConfig::r740_pac();
    AppModel::from_analysis_with_blocks(&an, &cfg.cpu, target_s, &BlockDb::standard()).unwrap()
}

fn plain_app(name: &str, src: &str, target_s: f64) -> AppModel {
    let an = analyze_source(name, src).unwrap();
    let cfg = VerifEnvConfig::r740_pac();
    AppModel::from_analysis(&an, &cfg.cpu, target_s).unwrap()
}

#[test]
fn gemm_block_is_detected_with_exact_span() {
    let an = analyze_source("gemm.c", workloads::GEMM_C).unwrap();
    let found = detect(&an, &BlockDb::standard());
    assert_eq!(found.len(), 1, "{found:?}");
    let b = &found[0];
    assert_eq!(b.kind, BlockKind::Matmul);
    assert_eq!(b.func, "gemm");
    // The triple loop is the first nest in the file: loops 0, 1, 2.
    assert_eq!(b.root.0, 0);
    assert_eq!(
        b.covered.iter().map(|id| id.0).collect::<Vec<_>>(),
        vec![0, 1, 2],
        "exact covered span"
    );
    // The root loop's source line is the `for` inside gemm().
    let root = &an.loops[b.root.0];
    assert_eq!(root.func, "gemm");
    assert_eq!(root.line, b.line);
}

#[test]
fn fft1d_block_is_detected_with_exact_span() {
    let an = analyze_source("fft1d.c", workloads::FFT1D_C).unwrap();
    let found = detect(&an, &BlockDb::standard());
    assert_eq!(found.len(), 1, "{found:?}");
    let b = &found[0];
    assert_eq!(b.kind, BlockKind::Fft);
    assert_eq!(b.func, "fft1d");
    assert_eq!(b.root.0, 0);
    assert_eq!(
        b.covered.iter().map(|id| id.0).collect::<Vec<_>>(),
        vec![0, 1],
        "the DFT double loop, nothing else"
    );
}

#[test]
fn mriq_19_loops_produce_zero_false_positive_blocks() {
    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    assert_eq!(an.n_loops(), 19);
    let found = detect(&an, &BlockDb::standard());
    assert!(found.is_empty(), "false positives on MRI-Q: {found:?}");
}

#[test]
fn loop_only_plans_are_bit_identical_to_pre_block_behavior() {
    // For EVERY bundled workload: measuring any plan whose block genes
    // are all zero must be bit-identical to the pre-block (loop-only)
    // model — same RNG stream, same ledger, same trace.
    for (name, src) in workloads::ALL {
        let plain = plain_app(name, src, 9.0);
        let with = blocks_app(name, src, 9.0);
        assert_eq!(
            with.genome_len(),
            plain.genome_len() + with.blocks.len(),
            "{name}: block genes append to the loop genome"
        );
        let env_a = VerifEnvConfig::r740_pac().build(77);
        let env_b = VerifEnvConfig::r740_pac().build(77);

        // CPU baseline.
        let a = env_a.measure_cpu_only(&plain);
        let b = env_b.measure_cpu_only(&with);
        assert_eq!(a.time_s, b.time_s, "{name} baseline time");
        assert_eq!(a.energy_ws, b.energy_ws, "{name} baseline energy");
        assert_eq!(a.report, b.report, "{name} baseline ledger");

        // A single-loop offload on two destinations, block genes zero.
        let mut loop_bits = vec![false; plain.genome_len()];
        if !loop_bits.is_empty() {
            loop_bits[0] = true;
        }
        let mut full_bits = loop_bits.clone();
        full_bits.extend(std::iter::repeat(false).take(with.blocks.len()));
        for dest in [DeviceKind::Gpu, DeviceKind::Fpga] {
            let a = env_a.measure(&plain, &loop_bits, dest, TransferMode::Batched);
            let b = env_b.measure(&with, &full_bits, dest, TransferMode::Batched);
            assert_eq!(a.time_s, b.time_s, "{name} on {dest}");
            assert_eq!(a.energy_ws, b.energy_ws, "{name} on {dest}");
            assert_eq!(a.report, b.report, "{name} on {dest} ledger");
            assert_eq!(a.trace, b.trace, "{name} on {dest} trace");
        }
    }
}

#[test]
fn gemm_front_has_a_block_plan_dominating_the_best_loop_only_plan() {
    // The acceptance criterion: exhaust the gemm plan space on the GPU.
    // The front must contain a block-substituted plan strictly better on
    // W·s than the best loop-only plan, and the all-CPU baseline stays
    // on the front.
    let plain = plain_app("gemm.c", workloads::GEMM_C, 14.0);
    let with = blocks_app("gemm.c", workloads::GEMM_C, 14.0);
    assert_eq!(with.blocks.len(), 1);
    let n_loops = with.candidates.len();

    let cfg = GpuFlowConfig {
        strategy: SearchStrategy::Exhaustive { max_bits: 12 },
        parallel_trials: false,
        ..Default::default()
    };
    let env = VerifEnvConfig::r740_pac().build(42);
    let loop_only = gpu_flow::run_on(&plain, &env, &cfg, DeviceKind::Gpu).unwrap();
    let env2 = VerifEnvConfig::r740_pac().build(42);
    let blocked = gpu_flow::run_on(&with, &env2, &cfg, DeviceKind::Gpu).unwrap();

    // Best loop-only plan (the whole space was measured, so this is the
    // true loop-only optimum under the paper scalarization).
    let best_loop_ws = loop_only.best.measurement.energy_ws;

    // Some block-substituted plan on the searched front strictly beats
    // it on W·s.
    let block_points: Vec<_> = blocked
        .search
        .front
        .points
        .iter()
        .filter(|s| s.genome.block_ones(n_loops) > 0)
        .collect();
    assert!(!block_points.is_empty(), "no block plan on the front");
    assert!(
        block_points
            .iter()
            .any(|s| s.objectives.energy_ws < best_loop_ws),
        "no block plan dominates the loop-only optimum on W·s \
         (best loop-only {best_loop_ws} W·s)"
    );
    // The winner itself substitutes the block and improves energy.
    assert!(blocked.best.pattern.genome.block_ones(n_loops) > 0);
    assert!(blocked.best.measurement.energy_ws < best_loop_ws);
    // The all-CPU baseline remains on the front.
    assert!(
        blocked.search.front.points.iter().any(|s| s.genome.ones() == 0),
        "baseline missing from the block-bearing front"
    );
}

#[test]
fn fft_block_wins_by_complexity_class() {
    // The library FFT replaces an O(n²) nest with O(n log n): on the
    // FPGA the block substitution must beat the best loop-only plan by a
    // wide margin on both time and energy.
    let with = blocks_app("fft1d.c", workloads::FFT1D_C, 14.0);
    assert_eq!(with.blocks.len(), 1);
    assert_eq!(with.blocks[0].detected.kind, BlockKind::Fft);
    let env = VerifEnvConfig::r740_pac().build(7);

    let baseline = env.measure_cpu_only(&with);
    let mut block_bits = vec![false; with.genome_len()];
    *block_bits.last_mut().unwrap() = true;
    let m = env.measure(&with, &block_bits, DeviceKind::Fpga, TransferMode::Batched);
    assert!(!m.timed_out, "{:?}", m.failure);
    assert!(
        m.energy_ws < baseline.energy_ws / 5.0,
        "block {} vs baseline {} W·s",
        m.energy_ws,
        baseline.energy_ws
    );
    assert!(m.time_s < baseline.time_s / 5.0);
    // The ledger attributes the substituted kernel to the accelerator.
    assert!(m.report.components.accelerator_ws > 0.0);
}

#[test]
fn histo_histogram_block_unlocks_a_non_parallelizable_loop() {
    // The histogram binning loop is rejected by the dependence analysis
    // (indirect store), so no loop gene covers it — but the block gene
    // substitutes an atomic device implementation and removes its host
    // time.
    let with = blocks_app("histo.c", workloads::HISTO_C, 14.0);
    assert_eq!(with.blocks.len(), 1);
    let b = &with.blocks[0];
    assert_eq!(b.detected.kind, BlockKind::Histogram);
    assert!(
        !with.candidates.contains(&b.detected.root),
        "the histogram loop must not be a loop-gene candidate"
    );
    let env = VerifEnvConfig::r740_pac().build(3);
    let baseline = env.measure_cpu_only(&with);
    let mut bits = vec![false; with.genome_len()];
    *bits.last_mut().unwrap() = true;
    let m = env.measure(&with, &bits, DeviceKind::Gpu, TransferMode::Batched);
    assert!(!m.timed_out, "{:?}", m.failure);
    assert!(m.time_s < baseline.time_s, "substitution must help");
}

#[test]
fn sched_trace_mixing_block_and_loop_workloads_is_bit_identical_per_seed() {
    // gemm (block-substituted), mriq (loop-only — no blocks detected)
    // and fft1d (block-substituted) through the power-budget scheduler
    // with block offloading enabled: the whole ledger must be a pure
    // function of (trace, config, seed).
    let trace = ArrivalTrace::parse(
        "0  gemm gpu\n\
         4  mriq fpga\n\
         9  fft1d fpga\n\
         15 gemm gpu\n",
    )
    .unwrap();
    let template = JobConfig {
        blocks: true,
        ga_flow: enadapt::offload::GpuFlowConfig {
            ga: enadapt::search::GaConfig {
                population: 6,
                generations: 4,
                ..Default::default()
            },
            parallel_trials: false,
            ..Default::default()
        },
        ..Default::default()
    };
    let cfg = SchedConfig {
        template,
        ..Default::default()
    };
    let a = run_sched(&trace, &cfg).unwrap();
    let b = run_sched(&trace, &cfg).unwrap();
    assert_eq!(
        a.to_json().to_string_compact(),
        b.to_json().to_string_compact(),
        "block-bearing sched ledger must be bit-identical per seed"
    );
    assert_eq!(a.admitted, 4);
    // At least one completed job ran a block-substituted deployment.
    let blocks_run: usize = a
        .jobs
        .iter()
        .filter_map(|j| match &j.outcome {
            SchedOutcome::Completed(c) => Some(c.blocks),
            _ => None,
        })
        .sum();
    assert!(blocks_run > 0, "no block deployment in the mixed trace");
    // And the table grew a block column.
    assert!(a.table().contains("blk"));
}
