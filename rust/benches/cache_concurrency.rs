//! Bench: sharded `MeasureCache` vs the legacy single-mutex cache under
//! concurrent lookup load (DESIGN.md §14).
//!
//! Two phases per implementation and thread count:
//!
//! 1. **Correctness (always asserted)** — the threads concurrently warm
//!    the same key set: the measure closure must run exactly once per
//!    distinct key, and the hit + miss totals must be exact (every
//!    non-first lookup a hit), never approximate.
//! 2. **Throughput** — a fixed total of warm lookups over 256 distinct
//!    keys is split across 1 / 4 / 16 threads, for the sharded store and
//!    for an in-bench reimplementation of the pre-§14 cache (one global
//!    `Mutex<HashMap>` in front of per-key slots — the exact lookup path
//!    this crate shipped before sharding). The per-thread-count
//!    lookups/sec series and the sharded-over-legacy speedup land in the
//!    JSON result.
//!
//! Environment knobs (see BENCH_cache.json):
//!
//! * `CACHE_ASSERT=1` — enforce the speedup floor (sharded >= 2x legacy
//!   lookups/sec at 16 threads). CI sets this; it stays opt-in because
//!   the ratio is meaningless on single-core boxes where neither
//!   implementation can overlap lookups.
//!
//! Emits a final JSON object on stdout for the perf dashboard.

use enadapt::canalyze::LoopId;
use enadapt::devices::{DeviceKind, TransferMode};
use enadapt::power::{ComponentEnergy, EnergyReport, PowerTrace};
use enadapt::util::benchkit::section;
use enadapt::util::json::Json;
use enadapt::util::measure_cache::{MeasureCache, MeasureKey};
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{Measurement, PhaseKind, TrialBreakdown};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

const KEYS: usize = 256;
const HAMMER_THREADS: usize = 8;
/// Total warm lookups per timed point, split across the thread count so
/// every point does the same amount of work.
const TOTAL_LOOKUPS: usize = 1 << 18;

type LegacySlot = Arc<Mutex<Option<Measurement>>>;

/// The pre-sharding cache, reproduced as the baseline: every lookup —
/// hit or miss — serializes on one global map mutex before reaching its
/// per-key slot.
#[derive(Default)]
struct LegacyCache {
    map: Mutex<HashMap<MeasureKey, LegacySlot>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl LegacyCache {
    fn get_or_measure(
        &self,
        key: MeasureKey,
        measure: impl FnOnce() -> Measurement,
    ) -> (Measurement, bool) {
        let slot = {
            let mut map = self.map.lock().unwrap();
            Arc::clone(map.entry(key).or_default())
        };
        let mut guard = slot.lock().unwrap();
        match &*guard {
            Some(m) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                (m.clone(), true)
            }
            None => {
                let m = measure();
                *guard = Some(m.clone());
                self.misses.fetch_add(1, Ordering::Relaxed);
                (m, false)
            }
        }
    }
}

fn fixture(time_s: f64) -> Measurement {
    Measurement {
        app: "t.c".into(),
        device: DeviceKind::Fpga,
        pattern: vec![true],
        regions: vec![LoopId(0)],
        time_s,
        mean_w: 111.0,
        energy_ws: time_s * 111.0,
        trace: PowerTrace::default(),
        report: EnergyReport {
            meter: "oracle".into(),
            sample_hz: 0.0,
            time_s,
            energy_ws: time_s * 111.0,
            mean_w: 111.0,
            peak_w: 125.0,
            profile_peak_w: 125.0,
            components: ComponentEnergy {
                idle_ws: time_s * 105.0,
                host_cpu_ws: time_s * 2.0,
                accelerator_ws: time_s * 3.0,
                transfer_ws: time_s * 1.0,
            },
        },
        timed_out: false,
        failure: None,
        breakdown: TrialBreakdown::default(),
        phase: PhaseKind::Verification,
    }
}

fn keys() -> Vec<MeasureKey> {
    (0..KEYS as u64)
        .map(|env| MeasureKey {
            app_hash: 7,
            pattern: vec![env % 2 == 0],
            plan: env / 2,
            device: DeviceKind::Fpga,
            xfer: TransferMode::Batched,
            env_fingerprint: env,
            dests: Vec::new(),
        })
        .collect()
}

/// A cache under test, erased to a lookup closure plus counter readers.
/// `lookup` must bump `evals` once per measure-closure execution.
struct UnderTest<'a> {
    name: &'static str,
    lookup: &'a (dyn Fn(MeasureKey) -> (Measurement, bool) + Sync),
    totals: &'a dyn Fn() -> (u64, u64),
    evals: &'a AtomicUsize,
}

/// Concurrently warm the cache — every thread looks up every key once —
/// then assert measure-once and exact totals. These assertions run
/// unconditionally, at every thread count, for both implementations.
fn warm_and_assert(cache: &UnderTest, ks: &[MeasureKey], threads: usize) {
    std::thread::scope(|s| {
        for t in 0..threads {
            let lookup = cache.lookup;
            s.spawn(move || {
                for i in 0..ks.len() {
                    // Rotate the start per thread so racers collide on
                    // different keys at the same moment.
                    let k = ks[(i + t * 17) % ks.len()].clone();
                    let (m, _) = lookup(k);
                    assert_eq!(m.time_s, 2.0);
                }
            });
        }
    });
    let (hits, misses) = (cache.totals)();
    assert_eq!(
        cache.evals.load(Ordering::SeqCst),
        ks.len(),
        "{}: measure-once violated",
        cache.name
    );
    assert_eq!(
        misses as usize,
        ks.len(),
        "{}: one miss per distinct key",
        cache.name
    );
    assert_eq!(
        hits as usize,
        threads * ks.len() - ks.len(),
        "{}: every non-first lookup must be a hit — totals exact",
        cache.name
    );
}

/// Timed phase: `TOTAL_LOOKUPS` warm lookups split across `threads`.
/// Returns lookups/sec. Asserts the counters moved by exactly the lookup
/// count, all hits (totals stay exact under contention, not approximate).
fn timed_lookups(cache: &UnderTest, ks: &[MeasureKey], threads: usize) -> f64 {
    let per_thread = TOTAL_LOOKUPS / threads;
    let (hits0, misses0) = (cache.totals)();
    let start = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let lookup = cache.lookup;
            let name = cache.name;
            s.spawn(move || {
                let mut acc = 0.0f64;
                for i in 0..per_thread {
                    let k = ks[(i + t * 17) % ks.len()].clone();
                    let (m, hit) = lookup(k);
                    assert!(hit, "{name}: warm lookup missed");
                    acc += m.time_s;
                }
                std::hint::black_box(acc);
            });
        }
    });
    let wall_s = start.elapsed().as_secs_f64();
    let (hits1, misses1) = (cache.totals)();
    assert_eq!(
        hits1 - hits0,
        (per_thread * threads) as u64,
        "{}: hit total must move by exactly the lookup count",
        cache.name
    );
    assert_eq!(
        misses1, misses0,
        "{}: warm phase must not miss",
        cache.name
    );
    (per_thread * threads) as f64 / wall_s.max(1e-9)
}

/// Run warm + timed for both implementations at one thread count.
/// Returns (sharded lookups/s, legacy lookups/s).
fn point(ks: &[MeasureKey], threads: usize) -> (f64, f64) {
    let sharded = MeasureCache::new();
    let sharded_evals = AtomicUsize::new(0);
    let sharded_lookup = |k: MeasureKey| {
        sharded.get_or_measure(k, || {
            sharded_evals.fetch_add(1, Ordering::SeqCst);
            fixture(2.0)
        })
    };
    let sharded_totals = || (sharded.hits(), sharded.misses());
    let under = UnderTest {
        name: "sharded",
        lookup: &sharded_lookup,
        totals: &sharded_totals,
        evals: &sharded_evals,
    };
    warm_and_assert(&under, ks, threads);
    let sharded_lps = timed_lookups(&under, ks, threads);

    let legacy = LegacyCache::default();
    let legacy_evals = AtomicUsize::new(0);
    let legacy_lookup = |k: MeasureKey| {
        legacy.get_or_measure(k, || {
            legacy_evals.fetch_add(1, Ordering::SeqCst);
            fixture(2.0)
        })
    };
    let legacy_totals = || {
        (
            legacy.hits.load(Ordering::Relaxed),
            legacy.misses.load(Ordering::Relaxed),
        )
    };
    let under = UnderTest {
        name: "legacy",
        lookup: &legacy_lookup,
        totals: &legacy_totals,
        evals: &legacy_evals,
    };
    warm_and_assert(&under, ks, threads);
    let legacy_lps = timed_lookups(&under, ks, threads);

    (sharded_lps, legacy_lps)
}

fn main() {
    let enforce = std::env::var("CACHE_ASSERT").as_deref() == Ok("1");
    let ks = keys();

    println!("=== cache_concurrency: sharded MeasureCache vs legacy single-mutex ===\n");

    section(&format!(
        "correctness: {HAMMER_THREADS} threads x {KEYS} colliding keys, both implementations"
    ));
    point(&ks, HAMMER_THREADS);
    println!("ok: measure-once held and hit+miss totals were exact on both implementations");

    section(&format!(
        "throughput: {TOTAL_LOOKUPS} warm lookups over {KEYS} keys at 1/4/16 threads"
    ));
    let mut table = Table::new(&[
        "threads",
        "sharded [lookups/s]",
        "legacy [lookups/s]",
        "speedup",
    ]);
    let mut series = Vec::new();
    let mut speedup_at_16 = 0.0;
    for threads in [1usize, 4, 16] {
        let (sharded_lps, legacy_lps) = point(&ks, threads);
        let speedup = sharded_lps / legacy_lps.max(1e-9);
        if threads == 16 {
            speedup_at_16 = speedup;
        }
        table.row(&[
            threads.to_string(),
            format!("{sharded_lps:.0}"),
            format!("{legacy_lps:.0}"),
            format!("{speedup:.2}x"),
        ]);
        series.push(Json::obj(vec![
            ("threads", Json::num(threads as f64)),
            ("sharded_lookups_per_s", Json::num(sharded_lps)),
            ("legacy_lookups_per_s", Json::num(legacy_lps)),
            ("speedup", Json::num(speedup)),
            ("hit_rate", Json::num(1.0)),
        ]));
    }
    println!("{}", table.render());

    if enforce {
        assert!(
            speedup_at_16 >= 2.0,
            "sharded cache is only {speedup_at_16:.2}x the single-mutex baseline at 16 \
             threads — under the 2x BENCH_cache.json floor"
        );
        println!("ok: {speedup_at_16:.2}x >= 2x speedup floor at 16 threads");
    } else {
        println!("(CACHE_ASSERT unset: speedup floor reported, not enforced)");
    }

    section("machine-readable result");
    println!(
        "{}",
        Json::obj(vec![
            ("bench", Json::str("cache_concurrency")),
            ("keys", Json::num(KEYS as f64)),
            ("total_lookups", Json::num(TOTAL_LOOKUPS as f64)),
            ("series", Json::arr(series)),
            ("speedup_at_16", Json::num(speedup_at_16)),
            (
                "correctness",
                Json::str("measure-once + exact totals asserted"),
            ),
        ])
        .to_string_pretty()
    );
}
