//! Bench: **§3.3** — mixed-environment destination selection.
//!
//! Regenerates the section's claims:
//!
//! * verification order many-core → GPU → FPGA;
//! * early stop when user requirements are met (and the search cost it
//!   saves — chiefly the hours-long FPGA compiles);
//! * power-aware vs time-only selection (can flip the chosen destination);
//! * the §3.3 datacenter cost model (initial ⅓ / operation ⅓ / other ⅓).

use enadapt::canalyze::analyze_source;
use enadapt::devices::DeviceKind;
use enadapt::search::{FitnessSpec, GaConfig};
use enadapt::offload::{mixed, DataCenterCost, GpuFlowConfig, MixedConfig, Requirements};
use enadapt::util::benchkit::{check_band, section};
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn main() {
    println!("=== mixed_selection: §3.3 destination selection in mixed environments ===");

    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();
    let ga_flow = GpuFlowConfig {
        ga: GaConfig {
            population: 12,
            generations: 10,
            ..Default::default()
        },
        ..Default::default()
    };

    let mut ok = true;

    section("requirement sweep: early stop & trials saved");
    let mut t = Table::new(&[
        "requirements (speedup / energy)",
        "verified",
        "skipped",
        "chosen",
        "trials",
        "search cost [h]",
    ]);
    for (label, req) in [
        ("any improvement (1x/1x)", Requirements::any_improvement()),
        ("moderate (3x/1.5x)", Requirements { min_speedup: 3.0, min_energy_ratio: 1.5 }),
        ("default (5x/2x)", Requirements::default()),
        ("impossible (∞/∞)", Requirements { min_speedup: f64::INFINITY, min_energy_ratio: f64::INFINITY }),
    ] {
        let env = VerifEnvConfig::r740_pac().build(7);
        let out = mixed::run(
            &app,
            &env,
            &MixedConfig {
                requirements: req,
                ga_flow,
                ..Default::default()
            },
        )
        .expect("mixed");
        t.row(&[
            label.to_string(),
            out.tried
                .iter()
                .map(|d| d.device.name())
                .collect::<Vec<_>>()
                .join("→"),
            out.skipped
                .iter()
                .map(|d| d.name())
                .collect::<Vec<_>>()
                .join(","),
            out.chosen.device.to_string(),
            out.tried.iter().map(|d| d.trials).sum::<u64>().to_string(),
            format!("{:.1}", env.search_cost_s() / 3600.0),
        ]);
        if label.starts_with("any") {
            // Lenient requirements must stop at the first destination.
            ok &= check_band("early stop at many-core", out.tried.len() as f64, 1.0, 1.0);
            ok &= check_band(
                "fpga skipped",
                out.skipped.contains(&DeviceKind::Fpga) as u8 as f64,
                1.0,
                1.0,
            );
        }
        if label.starts_with("impossible") {
            ok &= check_band("all three verified", out.tried.len() as f64, 3.0, 3.0);
            ok &= check_band(
                "order many-core→gpu→fpga",
                (out.tried[0].device == DeviceKind::ManyCore
                    && out.tried[1].device == DeviceKind::Gpu
                    && out.tried[2].device == DeviceKind::Fpga) as u8 as f64,
                1.0,
                1.0,
            );
        }
    }
    println!("{}", t.render());

    section("power-aware vs time-only selection (full verification)");
    let impossible = Requirements {
        min_speedup: f64::INFINITY,
        min_energy_ratio: f64::INFINITY,
    };
    let env = VerifEnvConfig::r740_pac().build(7);
    let aware = mixed::run(
        &app,
        &env,
        &MixedConfig {
            requirements: impossible,
            ga_flow,
            ..Default::default()
        },
    )
    .unwrap();
    let env = VerifEnvConfig::r740_pac().build(7);
    let mut cfg_time = MixedConfig {
        requirements: impossible,
        fitness: FitnessSpec::time_only(),
        ga_flow,
        ..Default::default()
    };
    cfg_time.ga_flow.fitness = FitnessSpec::time_only();
    cfg_time.fpga_flow.fitness = FitnessSpec::time_only();
    let timeonly = mixed::run(&app, &env, &cfg_time).unwrap();
    let mut t = Table::new(&["objective", "chosen", "time [s]", "power [W]", "energy [W*s]"]);
    for (label, out) in [("power-aware (paper)", &aware), ("time-only (previous)", &timeonly)] {
        t.row(&[
            label.to_string(),
            out.chosen.device.to_string(),
            format!("{:.2}", out.chosen.best.measurement.time_s),
            format!("{:.1}", out.chosen.best.measurement.mean_w),
            format!("{:.0}", out.chosen.best.measurement.energy_ws),
        ]);
    }
    println!("{}", t.render());
    ok &= check_band(
        "power-aware chooses FPGA on MRI-Q",
        (aware.chosen.device == DeviceKind::Fpga) as u8 as f64,
        1.0,
        1.0,
    );
    ok &= check_band(
        "power-aware energy ≤ time-only energy",
        timeonly.chosen.best.measurement.energy_ws / aware.chosen.best.measurement.energy_ws,
        1.0,
        10.0,
    );

    section("§3.3 datacenter cost model");
    let cost = DataCenterCost::default();
    let mut t = Table::new(&["scenario", "speedup", "energy ratio", "relative total cost"]);
    for (label, s, p) in [
        ("no offload", 1.0, 1.0),
        ("paper example: time 1/5, power 1/2", 5.0, 2.0),
        ("fig5 fpga result", 7.0, 7.6),
        ("gpu result (fast, power-hungry)", 9.0, 6.0),
    ] {
        t.row(&[
            label.to_string(),
            format!("{s:.1}x"),
            format!("{p:.1}x"),
            format!("{:.3}", cost.relative_cost(s, p)),
        ]);
    }
    println!("{}", t.render());
    ok &= check_band(
        "paper example cuts cost but < half",
        cost.relative_cost(5.0, 2.0),
        0.5,
        1.0,
    );

    println!(
        "\nmixed_selection: {}",
        if ok { "ALL BANDS PASS" } else { "SOME BANDS FAILED" }
    );
}
