//! Bench: telemetry overhead — the zero-overhead contract of the `obs`
//! layer (BENCH_obs.json, DESIGN.md §16).
//!
//! Two runs per sweep point over the BENCH_sched.json drifting trace on
//! the same 4-node / 800 W-cap cluster:
//!
//! * **off** — telemetry fully disabled (the default). Every obs entry
//!   point costs one relaxed atomic load and a predicted branch, so
//!   this run must stay within 2% of the plain `sched_scale` wall
//!   ceiling at the 100k point (`OBS_ASSERT=1` enforces it).
//! * **on** — all three pillars enabled (spans + metrics + series).
//!   The on-path is allowed to cost real time (it allocates span
//!   names and appends series rows), bounded by a generous on/off
//!   ratio ceiling — it exists to catch pathological regressions, not
//!   to promise the on-path is free.
//!
//! At every sweep point the off-run and on-run `SchedReport`s are
//! asserted byte-identical *unconditionally*: telemetry is purely
//! observational and must never perturb the ledger.
//!
//! Environment knobs (CI smoke uses both):
//!
//! * `OBS_SCALE_MAX` — largest arrival count to sweep (default 100000).
//! * `OBS_ASSERT=1` — enforce the BENCH_obs.json ceilings (off-path
//!   wall at 100k, on/off ratio).
//!
//! Emits a final JSON object on stdout for the perf dashboard.

use enadapt::coordinator::sched::run_sched;
use enadapt::coordinator::{ArrivalTrace, JobConfig, SchedConfig, SyntheticTraceConfig};
use enadapt::devices::NodeSpec;
use enadapt::obs;
use enadapt::offload::GpuFlowConfig;
use enadapt::power::IdlePolicy;
use enadapt::search::GaConfig;
use enadapt::util::benchkit::section;
use enadapt::util::json::Json;
use enadapt::util::tablefmt::Table;
use std::time::Instant;

/// Off-path wall ceiling at the 100k point: the 60 s BENCH_sched.json
/// ceiling plus the 2% telemetry-off regression allowance.
const OFF_WALL_CEILING_100K_S: f64 = 60.0 * 1.02;
/// Generous on/off wall ratio backstop (the on-path allocates).
const ON_OFF_RATIO_CEILING: f64 = 2.0;

fn template() -> JobConfig {
    JobConfig {
        ga_flow: GpuFlowConfig {
            ga: GaConfig {
                population: 8,
                generations: 6,
                ..Default::default()
            },
            parallel_trials: false,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn sweep_config() -> SchedConfig {
    SchedConfig {
        template: template(),
        nodes: (0..4).map(|i| NodeSpec::r740_pac(&format!("node{i}"))).collect(),
        fleet_watt_cap: Some(800.0),
        idle_policy: IdlePolicy::gate_after(30.0),
        ..Default::default()
    }
}

fn drifting_trace(n: usize) -> ArrivalTrace {
    let mut syn = SyntheticTraceConfig::standard(n, 1.0, 11);
    syn.drift_after = Some(n / 2);
    syn.drift_scale = 2.0;
    ArrivalTrace::poisson(&syn)
}

fn main() {
    let max_arrivals: usize = std::env::var("OBS_SCALE_MAX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100_000);
    let enforce = std::env::var("OBS_ASSERT").as_deref() == Ok("1");

    println!("=== obs_overhead: telemetry off vs on over the sched_scale drifting trace ===\n");

    section("off vs on sweep (4 nodes, 800 W cap, drift at the midpoint)");
    let mut table = Table::new(&[
        "arrivals",
        "off [ms]",
        "on [ms]",
        "ratio",
        "span events",
        "series rows",
        "identical report",
    ]);
    let mut series = Vec::new();
    for n in [1_000usize, 10_000, 100_000] {
        if n > max_arrivals {
            println!("(skipping {n} arrivals: OBS_SCALE_MAX = {max_arrivals})");
            continue;
        }
        let trace = drifting_trace(n);
        let cfg = sweep_config();

        obs::reset();
        let start = Instant::now();
        let off_report = run_sched(&trace, &cfg).expect("telemetry-off run");
        let off_wall_s = start.elapsed().as_secs_f64();
        let off_json = off_report.to_json().to_string_compact();

        obs::reset();
        obs::enable(obs::ALL);
        let start = Instant::now();
        let on_report = run_sched(&trace, &cfg).expect("telemetry-on run");
        let on_wall_s = start.elapsed().as_secs_f64();
        let on_json = on_report.to_json().to_string_compact();
        let span_events = obs::span::len();
        let series_rows = obs::series::power_steps().len();
        obs::reset();

        // The zero-perturbation contract, enforced unconditionally
        // (with or without OBS_ASSERT).
        assert_eq!(
            off_json, on_json,
            "telemetry changed the SchedReport at {n} arrivals"
        );

        let ratio = on_wall_s / off_wall_s.max(1e-9);
        table.row(&[
            n.to_string(),
            format!("{:.1}", off_wall_s * 1e3),
            format!("{:.1}", on_wall_s * 1e3),
            format!("{ratio:.3}x"),
            span_events.to_string(),
            series_rows.to_string(),
            "yes".to_string(),
        ]);
        series.push(Json::obj(vec![
            ("arrivals", Json::num(n as f64)),
            ("off_wall_s", Json::num(off_wall_s)),
            ("on_wall_s", Json::num(on_wall_s)),
            ("ratio", Json::num(ratio)),
            ("span_events", Json::num(span_events as f64)),
            ("series_rows", Json::num(series_rows as f64)),
            ("identical_report", Json::Bool(true)),
            ("admitted", Json::num(off_report.admitted as f64)),
            ("dropped", Json::num(off_report.dropped as f64)),
        ]));
        if enforce {
            if n == 100_000 {
                assert!(
                    off_wall_s <= OFF_WALL_CEILING_100K_S,
                    "telemetry-off run took {off_wall_s:.2} s at 100k arrivals — over \
                     the {OFF_WALL_CEILING_100K_S} s BENCH_obs.json ceiling"
                );
            }
            assert!(
                ratio <= ON_OFF_RATIO_CEILING,
                "telemetry-on run is {ratio:.2}x the off run at {n} arrivals — over \
                 the {ON_OFF_RATIO_CEILING}x BENCH_obs.json backstop"
            );
        }
    }
    println!("{}", table.render());

    section("machine-readable result");
    println!(
        "{}",
        Json::obj(vec![
            ("bench", Json::str("obs_overhead")),
            ("series", Json::arr(series)),
            (
                "off_wall_ceiling_100k_s",
                Json::num(OFF_WALL_CEILING_100K_S)
            ),
            ("on_off_ratio_ceiling", Json::num(ON_OFF_RATIO_CEILING)),
        ])
        .to_string_pretty()
    );
}
