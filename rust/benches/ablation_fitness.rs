//! Bench: ablation over the evaluation-value exponents (§3.3's "the
//! evaluation formula needs to be set differently for each business
//! operator") and the parallel-verification option.
//!
//! Sweeps `V = t^(-a) · p^(-b)` over operator profiles and reports which
//! destination/pattern each profile selects on MRI-Q, plus the §3.3 cost
//! model's verdict, plus wall-time of sequential vs parallel trials.

use enadapt::canalyze::analyze_source;
use enadapt::devices::DeviceKind;
use enadapt::search::{FitnessSpec, GaConfig};
use enadapt::offload::{gpu_flow, DataCenterCost, GpuFlowConfig};
use enadapt::util::benchkit::{bench, check_band, section};
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn main() {
    println!("=== ablation_fitness: evaluation-value exponents & trial parallelism ===");

    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();
    let ga = GaConfig {
        population: 12,
        generations: 10,
        ..Default::default()
    };

    section("operator profiles: V = t^(-a) · p^(-b)");
    let mut t = Table::new(&[
        "operator profile",
        "a (time)",
        "b (power)",
        "gpu best energy [W*s]",
        "fpga wins value?",
    ]);
    let mut ok = true;
    for (label, spec) in [
        ("time-only (previous papers)", FitnessSpec::time_only()),
        ("paper (balanced 1/2,1/2)", FitnessSpec::paper()),
        ("power-heavy operator", FitnessSpec::power_heavy()),
    ] {
        let env = VerifEnvConfig::r740_pac().build(21);
        let gpu = gpu_flow::run(
            &app,
            &env,
            &GpuFlowConfig {
                ga,
                fitness: spec,
                seed: 21,
                ..Default::default()
            },
        )
        .unwrap();
        // Compare the winning GPU pattern against the Fig. 5 FPGA result
        // under this operator's value.
        let fpga_best = {
            let outer = app
                .loops
                .iter()
                .max_by(|a, b| a.cpu_time_s.partial_cmp(&b.cpu_time_s).unwrap())
                .unwrap()
                .id;
            let pos = app.candidates.iter().position(|&c| c == outer).unwrap();
            let mut bits = vec![false; app.genome_len()];
            bits[pos] = true;
            env.measure(&app, &bits, DeviceKind::Fpga, Default::default())
        };
        let v_gpu = spec.value(
            gpu.best.measurement.time_s,
            gpu.best.measurement.mean_w,
            gpu.best.measurement.timed_out,
        );
        let v_fpga = spec.value(fpga_best.time_s, fpga_best.mean_w, fpga_best.timed_out);
        t.row(&[
            label.to_string(),
            format!("{:.2}", spec.time_exp),
            format!("{:.2}", spec.power_exp),
            format!("{:.0}", gpu.best.measurement.energy_ws),
            format!("{}", v_fpga > v_gpu),
        ]);
        if label.starts_with("time-only") {
            ok &= check_band("time-only: GPU wins", (v_fpga <= v_gpu) as u8 as f64, 1.0, 1.0);
        }
        if label.starts_with("power-heavy") {
            ok &= check_band("power-heavy: FPGA wins", (v_fpga > v_gpu) as u8 as f64, 1.0, 1.0);
        }
    }
    println!("{}", t.render());

    section("§3.3 cost model across exponent choices");
    let cost = DataCenterCost::default();
    println!(
        "  fig5 fpga (7.0x / 7.6x): relative cost {:.3}",
        cost.relative_cost(7.0, 7.6)
    );
    println!(
        "  gpu (9.4x / 6.5x):       relative cost {:.3}",
        cost.relative_cost(9.4, 6.5)
    );

    section("sequential vs parallel verification trials (wall time)");
    let seq = bench("gpu_flow sequential trials", 1, 5, || {
        let env = VerifEnvConfig::r740_pac().build(33);
        let out = gpu_flow::run(
            &app,
            &env,
            &GpuFlowConfig {
                ga,
                seed: 33,
                parallel_trials: false,
                ..Default::default()
            },
        )
        .unwrap();
        std::hint::black_box(out.best.value);
    });
    println!("{}", seq.row());
    let par = bench("gpu_flow parallel trials", 1, 5, || {
        let env = VerifEnvConfig::r740_pac().build(33);
        let out = gpu_flow::run(
            &app,
            &env,
            &GpuFlowConfig {
                ga,
                seed: 33,
                parallel_trials: true,
                ..Default::default()
            },
        )
        .unwrap();
        std::hint::black_box(out.best.value);
    });
    println!("{}", par.row());

    // Parallel and sequential must agree bit-for-bit (deterministic
    // per-pattern measurement noise).
    let env_a = VerifEnvConfig::r740_pac().build(33);
    let a = gpu_flow::run(
        &app,
        &env_a,
        &GpuFlowConfig { ga, seed: 33, parallel_trials: false, ..Default::default() },
    )
    .unwrap();
    let env_b = VerifEnvConfig::r740_pac().build(33);
    let b = gpu_flow::run(
        &app,
        &env_b,
        &GpuFlowConfig { ga, seed: 33, parallel_trials: true, ..Default::default() },
    )
    .unwrap();
    ok &= check_band(
        "parallel == sequential results",
        (a.best.pattern.genome == b.best.pattern.genome && a.best.value == b.best.value) as u8
            as f64,
        1.0,
        1.0,
    );

    println!(
        "\nablation_fitness: {}",
        if ok { "ALL BANDS PASS" } else { "SOME BANDS FAILED" }
    );
}
