//! Bench: **Fig. 3** — "Automatic FPGA offload method considering power
//! consumption" (the narrowing funnel).
//!
//! Regenerates the §3.2 flow on MRI-Q: 16 processable loops → intensity
//! cut → trip-count cut → precompile resource cut → **4 measured
//! patterns** (paper §4.1b) → combination round → final pattern, with the
//! per-stage search costs that justify narrowing over GA for FPGAs.

use enadapt::canalyze::analyze_source;
use enadapt::offload::{fpga_flow, FpgaFlowConfig};
use enadapt::util::benchkit::{bench, check_band, section};
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn main() {
    println!("=== fig3_narrowing: FPGA candidate narrowing funnel (MRI-Q) ===");

    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();
    let env = VerifEnvConfig::r740_pac().build(5);
    let out = fpga_flow::run(&app, &env, &FpgaFlowConfig::default()).expect("fpga flow");

    section("funnel (paper Fig. 3 stages)");
    let f = out.funnel;
    let mut t = Table::new(&["stage", "candidates", "paper"]);
    t.row(&["processable loop statements".into(), f.candidates.to_string(), "16".into()]);
    t.row(&["after arithmetic-intensity cut".into(), f.after_intensity.to_string(), "(high-AI subset)".into()]);
    t.row(&["after trip-count cut".into(), f.after_trips.to_string(), "(high-trip subset)".into()]);
    t.row(&["after precompile resource cut".into(), f.after_fit.to_string(), "(fits Arria10)".into()]);
    t.row(&["single patterns measured".into(), f.first_round.to_string(), "4".into()]);
    t.row(&["combination patterns measured".into(), f.second_round.to_string(), "(2nd round)".into()]);
    println!("{}", t.render());

    section("measured patterns (time & power — the §3.2 selection data)");
    let mut t = Table::new(&["round", "pattern", "time [s]", "power [W]", "energy [W*s]", "value"]);
    for (round, list) in [("single", &out.first_round), ("combo", &out.second_round)] {
        for e in list.iter() {
            t.row(&[
                round.to_string(),
                e.pattern.genome.to_string(),
                format!("{:.2}", e.measurement.time_s),
                format!("{:.1}", e.measurement.mean_w),
                format!("{:.0}", e.measurement.energy_ws),
                format!("{:.5}", e.value),
            ]);
        }
    }
    t.row(&[
        "FINAL".into(),
        out.best.pattern.genome.to_string(),
        format!("{:.2}", out.best.measurement.time_s),
        format!("{:.1}", out.best.measurement.mean_w),
        format!("{:.0}", out.best.measurement.energy_ws),
        format!("{:.5}", out.best.value),
    ]);
    println!("{}", t.render());

    section("search cost: narrowing vs hypothetical GA on FPGA");
    let compiles = f.first_round + f.second_round;
    let per_compile_h = env.cfg.fpga.synth.compile_base_s / 3600.0;
    let ga_patterns = 16 * 20; // pop x generations upper bound of distinct patterns
    println!(
        "  narrowing: {} full compiles ≈ {:.1} h total (measured {:.1} h incl. runs)",
        compiles,
        compiles as f64 * per_compile_h,
        out.search_cost_s / 3600.0
    );
    println!(
        "  GA (16×20) would need up to {} compiles ≈ {:.0} h — infeasible, which is \
         exactly why §3.2 narrows",
        ga_patterns,
        ga_patterns as f64 * per_compile_h
    );

    let mut ok = true;
    ok &= check_band("processable loops", f.candidates as f64, 16.0, 16.0);
    ok &= check_band("measured singles", f.first_round as f64, 4.0, 4.0);
    ok &= check_band(
        "funnel is monotone",
        (f.candidates >= f.after_intensity
            && f.after_intensity >= f.after_trips
            && f.after_trips >= f.after_fit
            && f.after_fit >= f.first_round) as u8 as f64,
        1.0,
        1.0,
    );
    ok &= check_band(
        "final beats baseline (value ratio)",
        out.best.value / out.baseline_value,
        1.5,
        50.0,
    );
    ok &= check_band(
        "narrowing search cost [h]",
        out.search_cost_s / 3600.0,
        4.0,
        80.0,
    );

    section("narrowing-stage wall time (L3)");
    println!(
        "{}",
        bench("fpga_flow::run (full funnel + trials)", 1, 10, || {
            let env = VerifEnvConfig::r740_pac().build(5);
            let o = fpga_flow::run(&app, &env, &FpgaFlowConfig::default()).unwrap();
            std::hint::black_box(o.best.value);
        })
        .row()
    );

    println!(
        "\nfig3_narrowing: {}",
        if ok { "ALL BANDS PASS" } else { "SOME BANDS FAILED" }
    );
}
