//! Bench: **funcblock_detect** — function-block detection cost and the
//! plan-space growth it buys.
//!
//! For every bundled workload it times the detection pass (idiom +
//! signature matching over the analyzed AST), reports what was found,
//! and compares the loop-only search space (2^loops) against the
//! block-bearing plan space (2^(loops+blocks)). Invariants checked:
//!
//! * gemm/fft1d/histo each detect exactly one block; mriq/stencil/vecadd
//!   detect none (the MRI-Q zero-false-positive guarantee);
//! * detection is fast enough to run inside every job (sub-millisecond
//!   per workload on any reasonable machine — checked loosely).

use enadapt::canalyze::analyze_source;
use enadapt::funcblock::{detect, BlockDb};
use enadapt::util::benchkit::{bench, check_band, section};
use enadapt::util::tablefmt::Table;
use enadapt::workloads;

fn main() {
    println!("=== funcblock_detect: block detection + plan-space sweep ===");
    let db = BlockDb::standard();

    section("per-workload detection outcome");
    let mut t = Table::new(&[
        "workload",
        "loops",
        "candidates",
        "blocks",
        "kinds",
        "loop plans",
        "block plans",
        "detect [us]",
    ]);
    let mut detected_counts = Vec::new();
    for (name, src) in workloads::ALL {
        let an = analyze_source(name, src).expect("analyze");
        let found = detect(&an, &db);
        let stat = bench(name, 3, 30, || {
            let f = detect(&an, &db);
            std::hint::black_box(f.len());
        });
        let candidates = an.parallelizable_ids().len();
        let kinds: Vec<String> = found.iter().map(|b| b.kind.to_string()).collect();
        t.row(&[
            (*name).to_string(),
            an.n_loops().to_string(),
            candidates.to_string(),
            found.len().to_string(),
            if kinds.is_empty() {
                "-".to_string()
            } else {
                kinds.join(",")
            },
            format!("2^{}", candidates),
            format!("2^{}", candidates + found.len()),
            format!("{:.1}", stat.mean_s * 1e6),
        ]);
        detected_counts.push(((*name).to_string(), found.len()));
    }
    println!("{}", t.render());

    section("invariants");
    let mut ok = true;
    for (name, expect) in [
        ("mriq", 0usize),
        ("stencil", 0),
        ("vecadd", 0),
        ("gemm", 1),
        ("fft1d", 1),
        ("histo", 1),
    ] {
        let got = detected_counts
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, c)| *c)
            .unwrap_or(usize::MAX);
        ok &= check_band(
            &format!("{name} detected blocks"),
            got as f64,
            expect as f64,
            expect as f64,
        );
    }
    std::process::exit(if ok { 0 } else { 1 });
}
