//! Bench: power-budget scheduler scale sweep — 10 → 1,000,000
//! trace-driven arrivals on a 4-node cluster under a fleet Watt cap, with
//! a mid-trace input-growth drift that exercises the re-adaptation loop.
//!
//! What this measures: the event-driven engine (heap-merged completions,
//! indexed occupancy, interned deployments, memoized arrivals) plus
//! shared-measurement-cache behavior at fleet scale. Deployments are
//! bounded by the workload × destination mix (12 here), so arrival
//! 1,000,000 costs a memo lookup, not a search — the hit rate climbs
//! toward 100% as the trace grows while arrivals/sec stays high. Every
//! run reports the fleet W·s ledger against the all-CPU-everywhere
//! counterfactual (the paper's Fig. 5 comparison at cluster scale).
//!
//! At the 10k point the retained time-stepped reference loop
//! (`legacy_loop`) is run too and its JSON report asserted bit-identical
//! to the event engine's — the equivalence contract of BENCH_sched.json.
//! A federated `--clusters 4` point exercises the sharded coordinator at
//! the 100k scale, serially and with `--parallel-clusters`, asserting the
//! two reports byte-identical.
//!
//! Environment knobs (CI smoke uses both):
//!
//! * `SCHED_SCALE_MAX` — largest arrival count to sweep (default
//!   1000000; CI smoke sets 100000).
//! * `SCHED_SCALE_ASSERT=1` — enforce the BENCH_sched.json wall-clock
//!   ceilings (100k ≤ 60 s, 1M ≤ 10 s for the engine sweep points) so
//!   scalability regressions fail loudly instead of just reading slow.
//!
//! Emits a final JSON object on stdout for the perf dashboard.

use enadapt::coordinator::sched::federation::{run_federated, FederationConfig};
use enadapt::coordinator::sched::run_sched;
use enadapt::coordinator::{ArrivalTrace, JobConfig, SchedConfig, SyntheticTraceConfig};
use enadapt::devices::NodeSpec;
use enadapt::offload::GpuFlowConfig;
use enadapt::power::IdlePolicy;
use enadapt::search::GaConfig;
use enadapt::util::benchkit::section;
use enadapt::util::json::Json;
use enadapt::util::tablefmt::Table;
use std::time::Instant;

fn template() -> JobConfig {
    JobConfig {
        ga_flow: GpuFlowConfig {
            ga: GaConfig {
                population: 8,
                generations: 6,
                ..Default::default()
            },
            parallel_trials: false,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn cluster() -> Vec<NodeSpec> {
    (0..4).map(|i| NodeSpec::r740_pac(&format!("node{i}"))).collect()
}

fn sweep_config() -> SchedConfig {
    SchedConfig {
        template: template(),
        nodes: cluster(),
        fleet_watt_cap: Some(800.0),
        idle_policy: IdlePolicy::gate_after(30.0),
        ..Default::default()
    }
}

fn drifting_trace(n: usize) -> ArrivalTrace {
    let mut syn = SyntheticTraceConfig::standard(n, 1.0, 11);
    syn.drift_after = Some(n / 2);
    syn.drift_scale = 2.0;
    ArrivalTrace::poisson(&syn)
}

/// Wall-clock ceiling for a sweep point, seconds (BENCH_sched.json).
fn wall_ceiling_s(n: usize) -> Option<f64> {
    match n {
        100_000 => Some(60.0),
        1_000_000 => Some(10.0),
        _ => None,
    }
}

fn main() {
    let max_arrivals: usize = std::env::var("SCHED_SCALE_MAX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let enforce = std::env::var("SCHED_SCALE_ASSERT").as_deref() == Ok("1");

    println!("=== sched_scale: trace-driven arrivals, fleet Watt cap, drift mid-trace ===\n");

    section("arrival-count sweep (4 nodes, 800 W cap, drift at the midpoint)");
    let mut table = Table::new(&[
        "arrivals",
        "admitted",
        "dropped",
        "reconfigs",
        "wall [ms]",
        "arrivals/s",
        "hit rate",
        "jobs [W*s]",
        "cpu-only [W*s]",
        "reduction",
    ]);
    let mut series = Vec::new();
    for n in [10usize, 100, 1_000, 10_000, 100_000, 1_000_000] {
        if n > max_arrivals {
            println!("(skipping {n} arrivals: SCHED_SCALE_MAX = {max_arrivals})");
            continue;
        }
        let trace = drifting_trace(n);
        let cfg = sweep_config();
        let start = Instant::now();
        let report = run_sched(&trace, &cfg).expect("sched run");
        let wall_s = start.elapsed().as_secs_f64();
        let hit_rate = report.cache_hits as f64
            / ((report.cache_hits + report.cache_misses) as f64).max(1.0);
        table.row(&[
            n.to_string(),
            report.admitted.to_string(),
            report.dropped.to_string(),
            report.reconfigs.len().to_string(),
            format!("{:.1}", wall_s * 1e3),
            format!("{:.0}", n as f64 / wall_s.max(1e-9)),
            format!("{:.0}%", hit_rate * 100.0),
            format!("{:.0}", report.production.total_ws()),
            format!("{:.0}", report.counterfactual_ws),
            format!("{:.1}x", report.jobs_reduction()),
        ]);
        series.push(Json::obj(vec![
            ("arrivals", Json::num(n as f64)),
            ("admitted", Json::num(report.admitted as f64)),
            ("dropped", Json::num(report.dropped as f64)),
            ("reconfigs", Json::num(report.reconfigs.len() as f64)),
            ("wall_s", Json::num(wall_s)),
            ("arrivals_per_s", Json::num(n as f64 / wall_s.max(1e-9))),
            ("cache_hit_rate", Json::num(hit_rate)),
            ("jobs_ws", Json::num(report.production.total_ws())),
            ("counterfactual_ws", Json::num(report.counterfactual_ws)),
            ("reduction", Json::num(report.jobs_reduction())),
            ("searches", Json::num(report.searches as f64)),
            ("horizon_s", Json::num(report.horizon_s)),
        ]));
        if enforce {
            if let Some(ceiling) = wall_ceiling_s(n) {
                assert!(
                    wall_s <= ceiling,
                    "{n} arrivals took {wall_s:.2} s — over the {ceiling} s \
                     BENCH_sched.json ceiling"
                );
            }
        }
    }
    println!("{}", table.render());

    // Equivalence contract: the event engine and the retained
    // time-stepped reference loop must fold the identical report at the
    // 10k standard point.
    let mut legacy_equiv_10k = Json::Null;
    if max_arrivals >= 10_000 {
        section("legacy-loop equivalence (10k arrivals, bit-identical JSON)");
        let trace = drifting_trace(10_000);
        let event = run_sched(&trace, &sweep_config()).expect("event engine");
        let start = Instant::now();
        let legacy = run_sched(
            &trace,
            &SchedConfig {
                legacy_loop: true,
                ..sweep_config()
            },
        )
        .expect("reference loop");
        let legacy_wall_s = start.elapsed().as_secs_f64();
        assert_eq!(
            event.to_json().to_string_compact(),
            legacy.to_json().to_string_compact(),
            "event engine and reference loop disagree at 10k arrivals"
        );
        println!(
            "ok: identical {}-job ledgers (reference loop took {:.1} ms)\n",
            event.jobs.len(),
            legacy_wall_s * 1e3
        );
        legacy_equiv_10k = Json::Bool(true);
    }

    // Federation point: the same drifting trace sharded across 4
    // clusters with the Watt budget rebalanced by probed demand — run
    // serially and then with parallel clusters, asserted byte-identical
    // (the --parallel-clusters contract of BENCH_sched.json).
    let mut federated = Json::Null;
    if max_arrivals >= 100_000 {
        section("federated sweep point (100k arrivals, --clusters 4, serial vs parallel)");
        let trace = drifting_trace(100_000);
        let fcfg = FederationConfig {
            base: sweep_config(),
            clusters: 4,
            shard_seed: 1,
            ..Default::default()
        };
        let start = Instant::now();
        let report = run_federated(&trace, &fcfg).expect("federated run");
        let wall_s = start.elapsed().as_secs_f64();
        let par_cfg = FederationConfig {
            parallel: true,
            ..fcfg
        };
        let par_start = Instant::now();
        let par_report = run_federated(&trace, &par_cfg).expect("parallel federated run");
        let par_wall_s = par_start.elapsed().as_secs_f64();
        assert_eq!(
            report.to_json().to_string_compact(),
            par_report.to_json().to_string_compact(),
            "parallel clusters changed the federation report"
        );
        println!("{}", report.table());
        println!(
            "parallel clusters: identical report, wall {:.1} ms vs {:.1} ms serial \
             ({:.2}x)\n",
            par_wall_s * 1e3,
            wall_s * 1e3,
            wall_s / par_wall_s.max(1e-9)
        );
        federated = Json::obj(vec![
            ("arrivals", Json::num(100_000.0)),
            ("clusters", Json::num(4.0)),
            ("admitted", Json::num(report.admitted as f64)),
            ("dropped", Json::num(report.dropped as f64)),
            ("wall_s", Json::num(wall_s)),
            (
                "arrivals_per_s",
                Json::num(100_000.0 / wall_s.max(1e-9)),
            ),
            ("parallel_wall_s", Json::num(par_wall_s)),
            ("parallel_identical", Json::Bool(true)),
            ("jobs_ws", Json::num(report.production.total_ws())),
            ("counterfactual_ws", Json::num(report.counterfactual_ws)),
            ("reduction", Json::num(report.jobs_reduction())),
        ]);
    }

    section("machine-readable result");
    println!(
        "{}",
        Json::obj(vec![
            ("bench", Json::str("sched_scale")),
            ("series", Json::arr(series)),
            ("legacy_equiv_10k", legacy_equiv_10k),
            ("federated_100k", federated),
        ])
        .to_string_pretty()
    );
}
