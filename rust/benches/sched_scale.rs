//! Bench: power-budget scheduler scale sweep — 10 → 10,000 trace-driven
//! arrivals on a 4-node cluster under a fleet Watt cap, with a mid-trace
//! input-growth drift that exercises the re-adaptation loop.
//!
//! What this measures: the event loop plus shared-measurement-cache
//! behavior at fleet scale. Deployments are bounded by the workload ×
//! destination mix (12 here), so arrival 10,000 costs two cache lookups,
//! not a search — the hit rate should climb toward 100% as the trace
//! grows while arrivals/sec stays high. Every run reports the fleet W·s
//! ledger against the all-CPU-everywhere counterfactual (the paper's
//! Fig. 5 comparison at cluster scale).
//!
//! Emits a final JSON object on stdout for the perf dashboard.

use enadapt::coordinator::sched::run_sched;
use enadapt::coordinator::{ArrivalTrace, JobConfig, SchedConfig, SyntheticTraceConfig};
use enadapt::devices::NodeSpec;
use enadapt::offload::GpuFlowConfig;
use enadapt::power::IdlePolicy;
use enadapt::search::GaConfig;
use enadapt::util::benchkit::section;
use enadapt::util::json::Json;
use enadapt::util::tablefmt::Table;
use std::time::Instant;

fn template() -> JobConfig {
    JobConfig {
        ga_flow: GpuFlowConfig {
            ga: GaConfig {
                population: 8,
                generations: 6,
                ..Default::default()
            },
            parallel_trials: false,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn cluster() -> Vec<NodeSpec> {
    (0..4).map(|i| NodeSpec::r740_pac(&format!("node{i}"))).collect()
}

fn main() {
    println!("=== sched_scale: trace-driven arrivals, fleet Watt cap, drift mid-trace ===\n");

    section("arrival-count sweep (4 nodes, 800 W cap, drift at the midpoint)");
    let mut table = Table::new(&[
        "arrivals",
        "admitted",
        "dropped",
        "reconfigs",
        "wall [ms]",
        "arrivals/s",
        "hit rate",
        "jobs [W*s]",
        "cpu-only [W*s]",
        "reduction",
    ]);
    let mut series = Vec::new();
    for n in [10usize, 100, 1_000, 10_000] {
        let mut syn = SyntheticTraceConfig::standard(n, 1.0, 11);
        syn.drift_after = Some(n / 2);
        syn.drift_scale = 2.0;
        let trace = ArrivalTrace::poisson(&syn);
        let cfg = SchedConfig {
            template: template(),
            nodes: cluster(),
            fleet_watt_cap: Some(800.0),
            idle_policy: IdlePolicy::gate_after(30.0),
            ..Default::default()
        };
        let start = Instant::now();
        let report = run_sched(&trace, &cfg).expect("sched run");
        let wall_s = start.elapsed().as_secs_f64();
        let hit_rate = report.cache_hits as f64
            / ((report.cache_hits + report.cache_misses) as f64).max(1.0);
        table.row(&[
            n.to_string(),
            report.admitted.to_string(),
            report.dropped.to_string(),
            report.reconfigs.len().to_string(),
            format!("{:.1}", wall_s * 1e3),
            format!("{:.0}", n as f64 / wall_s.max(1e-9)),
            format!("{:.0}%", hit_rate * 100.0),
            format!("{:.0}", report.production.total_ws()),
            format!("{:.0}", report.counterfactual_ws),
            format!("{:.1}x", report.jobs_reduction()),
        ]);
        series.push(Json::obj(vec![
            ("arrivals", Json::num(n as f64)),
            ("admitted", Json::num(report.admitted as f64)),
            ("dropped", Json::num(report.dropped as f64)),
            ("reconfigs", Json::num(report.reconfigs.len() as f64)),
            ("wall_s", Json::num(wall_s)),
            ("arrivals_per_s", Json::num(n as f64 / wall_s.max(1e-9))),
            ("cache_hit_rate", Json::num(hit_rate)),
            ("jobs_ws", Json::num(report.production.total_ws())),
            ("counterfactual_ws", Json::num(report.counterfactual_ws)),
            ("reduction", Json::num(report.jobs_reduction())),
            ("searches", Json::num(report.searches as f64)),
            ("horizon_s", Json::num(report.horizon_s)),
        ]));
    }
    println!("{}", table.render());

    section("machine-readable result");
    println!(
        "{}",
        Json::obj(vec![
            ("bench", Json::str("sched_scale")),
            ("series", Json::arr(series)),
        ])
        .to_string_pretty()
    );
}
