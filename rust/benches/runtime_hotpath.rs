//! Bench: runtime + coordinator hot paths (the §Perf harness).
//!
//! Not a paper figure — this is deliverable (e): profile and optimize the
//! stack. Measures:
//!
//! * PJRT execute latency of each AOT artifact (L2 path, real execution);
//! * input-literal construction cost (the L3→PJRT boundary);
//! * the verifier's measurement loop (the L3 hot path the GA hammers);
//! * end-to-end Steps 1–7 job wall time;
//! * GA engine + analyzer throughput.
//!
//! Results land in EXPERIMENTS.md §Perf (before/after iterations).

use enadapt::canalyze::analyze_source;
use enadapt::coordinator::{run_job, Destination, JobConfig};
use enadapt::devices::DeviceKind;
use enadapt::runtime;
use enadapt::search::{run_synthetic, GaConfig, GaStrategy};
use enadapt::util::benchkit::{bench, section};
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn main() {
    println!("=== runtime_hotpath: L1/L2/L3 hot-path wall times ===");

    // --- L2: real PJRT execution of the AOT artifacts. ------------------
    section("PJRT execute (real HLO, per artifact)");
    match runtime::load_artifacts(&runtime::default_dir()) {
        Ok(arts) if arts.complete() => {
            let rt = runtime::HloRuntime::cpu().expect("cpu client");
            for v in &arts.variants {
                let model = rt.load_artifact(v).expect("load");
                let inputs = model.synth_inputs();
                let s = bench(&format!("execute {}", v.name), 2, 20, || {
                    let r = model.exe.run_f32(&inputs).unwrap();
                    std::hint::black_box(r.outputs.len());
                });
                println!("{}", s.row());
                // FLOP-rate estimate for the large variants.
                let flops = 2.0 * 14.0 * v.num_k as f64 * v.num_x as f64;
                println!(
                    "    ≈ {:.2} GFLOP/s effective ({}x{} Q accumulation)",
                    flops / s.median_s / 1e9,
                    v.num_k,
                    v.num_x
                );
            }
            section("input-literal construction (L3→PJRT boundary)");
            let v = arts.variant("mriq_cpu_large").unwrap();
            println!(
                "{}",
                bench("synth_mriq_inputs(512, 4096)", 2, 50, || {
                    let i = runtime::synth_mriq_inputs(v.num_k, v.num_x);
                    std::hint::black_box(i.len());
                })
                .row()
            );
            section("compile cost (once per variant at startup)");
            println!(
                "{}",
                bench("load+compile mriq_cpu_small", 1, 5, || {
                    let m = rt.load_artifact(arts.variant("mriq_cpu_small").unwrap()).unwrap();
                    std::hint::black_box(m.exe.name.len());
                })
                .row()
            );
        }
        _ => println!("  (artifacts not built — run `make artifacts`; skipping PJRT benches)"),
    }

    // --- L3: verifier + flows. -------------------------------------------
    section("verifier measurement loop (GA hot path)");
    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();
    let env = VerifEnvConfig::r740_pac().build(3);
    let bits: Vec<bool> = (0..app.genome_len()).map(|i| i % 3 == 0).collect();
    println!(
        "{}",
        bench("measure(gpu, 16-gene pattern)", 5, 200, || {
            let m = env.measure(&app, &bits, DeviceKind::Gpu, Default::default());
            std::hint::black_box(m.energy_ws);
        })
        .row()
    );
    println!(
        "{}",
        bench("AppModel::from_analysis(mriq)", 2, 50, || {
            let a = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();
            std::hint::black_box(a.genome_len());
        })
        .row()
    );

    section("analyzer (Steps 1-2) & GA engine");
    println!(
        "{}",
        bench("analyze_source(mriq.c) full profile", 1, 10, || {
            let a = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
            std::hint::black_box(a.n_loops());
        })
        .row()
    );
    println!(
        "{}",
        bench("ga strategy 16x20 synthetic", 2, 20, || {
            let r = run_synthetic(
                &GaStrategy {
                    cfg: GaConfig::default(),
                },
                16,
                9,
                |g| g.ones() as f64,
            )
            .unwrap();
            std::hint::black_box(r.best_value);
        })
        .row()
    );

    section("end-to-end Steps 1-7 job");
    println!(
        "{}",
        bench("run_job(mriq, fpga)", 1, 5, || {
            let cfg = JobConfig {
                destination: Destination::Device(DeviceKind::Fpga),
                ..Default::default()
            };
            let r = run_job("mriq.c", workloads::MRIQ_C, &cfg).unwrap();
            std::hint::black_box(r.trials);
        })
        .row()
    );
}
