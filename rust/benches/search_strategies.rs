//! Bench: **search_strategies** — the pluggable search layer compared on
//! one workload (MRI-Q → GPU destination).
//!
//! For each strategy (GA, deterministic annealing, exhaustive) it reports
//! the best scalarized value, the Pareto-front size, the measured-trials
//! count (the real search cost — verification trials are the expensive
//! resource) and the wall time, then checks the ordering invariants:
//!
//! * exhaustive is ground truth — no strategy beats its best value;
//! * the GA improves on the all-CPU baseline;
//! * every front contains the baseline point (strictly lowest exact peak).

use enadapt::canalyze::analyze_source;
use enadapt::devices::DeviceKind;
use enadapt::offload::{gpu_flow, GpuFlowConfig};
use enadapt::search::{AnnealConfig, GaConfig, SearchStrategy};
use enadapt::util::benchkit::{check_band, section};
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;
use std::time::Instant;

fn main() {
    println!("=== search_strategies: GA vs annealing vs exhaustive on MRI-Q/GPU ===");

    let an = analyze_source("mriq.c", workloads::MRIQ_C).expect("analyze");
    let base_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &base_cfg.cpu, 14.0).expect("app model");

    let strategies = [
        ("ga", SearchStrategy::Ga),
        ("anneal", SearchStrategy::Anneal(AnnealConfig::default())),
        (
            "exhaustive",
            SearchStrategy::Exhaustive { max_bits: 16 },
        ),
    ];

    section("per-strategy search outcome (same seed, same guide)");
    let mut t = Table::new(&[
        "strategy",
        "best value",
        "best pattern",
        "front",
        "measured",
        "archive hits",
        "wall [s]",
    ]);
    let mut results = Vec::new();
    for (label, strategy) in strategies {
        let env = VerifEnvConfig::r740_pac().build(42);
        let cfg = GpuFlowConfig {
            ga: GaConfig::default(),
            strategy,
            seed: 42,
            parallel_trials: false,
            ..Default::default()
        };
        let start = Instant::now();
        let out = gpu_flow::run_on(&app, &env, &cfg, DeviceKind::Gpu).expect("search");
        let wall = start.elapsed().as_secs_f64();
        t.row(&[
            label.to_string(),
            format!("{:.6}", out.best.value),
            out.best.pattern.genome.to_string(),
            out.search.front.len().to_string(),
            out.search.measured.to_string(),
            out.search.cache_hits.to_string(),
            format!("{wall:.3}"),
        ]);
        results.push((label, out));
    }
    println!("{}", t.render());

    let mut ok = true;
    let exhaustive = &results
        .iter()
        .find(|(l, _)| *l == "exhaustive")
        .unwrap()
        .1;
    for (label, out) in &results {
        ok &= check_band(
            &format!("{label} best ≤ exhaustive optimum (ratio)"),
            out.best.value / exhaustive.best.value,
            0.0,
            1.0 + 1e-12,
        );
        if !out
            .search
            .front
            .points
            .iter()
            .any(|s| s.genome.ones() == 0)
        {
            println!("FAIL [{label}] front lacks the all-CPU baseline point");
            ok = false;
        }
    }
    let ga = &results.iter().find(|(l, _)| *l == "ga").unwrap().1;
    ok &= check_band(
        "ga improves on the baseline (value ratio)",
        ga.best.value / ga.baseline_value,
        1.5,
        50.0,
    );
    ok &= check_band(
        "exhaustive measured the whole 16-bit space",
        exhaustive.search.measured as f64,
        65536.0,
        65536.0,
    );
    // Search-cost ordering: the annealer and GA measure a tiny fraction
    // of the space the exhaustive sweep pays for.
    ok &= check_band(
        "ga measured-trials share of the space",
        ga.search.measured as f64 / 65536.0,
        0.0,
        0.05,
    );

    println!(
        "\nsearch_strategies: {}",
        if ok { "ALL BANDS PASS" } else { "SOME BANDS FAILED" }
    );
    if !ok {
        std::process::exit(1);
    }
}
