//! Bench: tree-walking vs lowered profiling interpreter (DESIGN.md §13).
//!
//! The canalyze profiler now runs on a pre-lowered, index-addressed op IR
//! with profile-guided dispatch ordering and superinstructions
//! (`canalyze::lower`). The tree-walker (`canalyze::profile`) is retained
//! as the semantics-defining reference. This bench:
//!
//! * asserts — unconditionally, before any timing — that both
//!   interpreters produce bit-identical `ProfileData` (incl. `printed`)
//!   on every registered workload;
//! * measures tree-walk vs lowered wall time per workload and reports the
//!   speedup (the ISSUE target is ≥2× on mriq — measured, and enforced
//!   only under `CANALYZE_PGO_ASSERT=1`);
//! * measures the one-time `lower()` cost and the full
//!   `analyze_source(mriq)` pipeline;
//! * dumps the mriq opcode/pair histogram (`count_ops`) — the evidence
//!   behind the dispatch layout;
//! * emits a JSON block matching BENCH_canalyze.json `series_schema`.
//!
//! Env knobs:
//!
//! * `CANALYZE_PGO_ASSERT=1` — enforce the BENCH_canalyze.json ceilings
//!   (CI does); without it, missed ceilings are informational.

use enadapt::canalyze::loops::extract_loops;
use enadapt::canalyze::lower::lower;
use enadapt::canalyze::parser::parse;
use enadapt::canalyze::profile::profile;
use enadapt::canalyze::{analyze_source, analyze_source_with_limits, ProfileLimits};
use enadapt::util::benchkit::{bench, check_band, section};
use enadapt::util::json::Json;
use enadapt::workloads;

fn main() {
    let enforce = std::env::var("CANALYZE_PGO_ASSERT").as_deref() == Ok("1");
    println!("=== canalyze_pgo: tree-walker vs lowered op-IR interpreter ===");
    if enforce {
        println!("(CANALYZE_PGO_ASSERT=1 — enforcing BENCH_canalyze.json ceilings)");
    }

    let limits = ProfileLimits::default();
    let mut series: Vec<Json> = Vec::new();
    let mut mriq_speedup = 0.0f64;

    section("per-workload interpreter wall time (bit-equality asserted first)");
    for (name, src) in workloads::ALL {
        let prog = parse(name, src).expect("bundled workload parses");
        let table = extract_loops(&prog);
        let unit = lower(&prog, &table).expect("bundled workload lowers");
        // The contract comes first: both interpreters must agree bitwise
        // before any timing is worth reporting (BENCH_canalyze.json
        // "equivalence" — MeasureCache fingerprints, sched ledgers and
        // funcblock detection all consume this profile downstream).
        let t = profile(&prog, &table, limits).expect("tree-walker runs");
        let l = unit.run(&table, limits).expect("lowered interpreter runs");
        assert!(
            t.bits_eq(&l),
            "{name}: lowered profile diverges from the tree-walker"
        );

        let st = bench(&format!("tree-walk  {name}"), 1, 10, || {
            std::hint::black_box(profile(&prog, &table, limits).unwrap().steps);
        });
        let sl = bench(&format!("lowered    {name}"), 1, 10, || {
            std::hint::black_box(unit.run(&table, limits).unwrap().steps);
        });
        let slo = bench(&format!("lower()    {name}"), 2, 30, || {
            std::hint::black_box(lower(&prog, &table).unwrap().op_count());
        });
        println!("{}", st.row());
        println!("{}", sl.row());
        println!("{}", slo.row());
        let speedup = st.median_s / sl.median_s;
        println!(
            "    speedup {speedup:.2}x  ({} interpreted steps, {} lowered ops)",
            t.steps,
            unit.op_count()
        );
        if *name == "mriq" {
            mriq_speedup = speedup;
        }
        series.push(Json::obj(vec![
            ("workload", Json::str(*name)),
            ("tree_s", Json::num(st.median_s)),
            ("lowered_s", Json::num(sl.median_s)),
            ("lower_s", Json::num(slo.median_s)),
            ("speedup", Json::num(speedup)),
            ("steps", Json::num(t.steps as f64)),
            ("ops", Json::num(unit.op_count() as f64)),
        ]));
    }

    section("full pipeline: analyze_source(mriq) — parse + sem + loops + lowered profile");
    let sa = bench("analyze_source(mriq.c)", 1, 10, || {
        let a = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
        std::hint::black_box(a.n_loops());
    });
    println!("{}", sa.row());

    section("mriq opcode/pair histogram (count_ops) — the PGO evidence");
    let counted = ProfileLimits {
        count_ops: true,
        ..Default::default()
    };
    let an = analyze_source_with_limits("mriq.c", workloads::MRIQ_C, counted)
        .expect("counted analyze runs");
    let ops = an.op_profile.expect("count_ops was set");
    println!("{}", ops.render());

    section("ceilings (BENCH_canalyze.json)");
    let mut ok = true;
    ok &= check_band(
        "mriq interpreter speedup (lowered vs tree-walk)",
        mriq_speedup,
        2.0,
        f64::INFINITY,
    );
    ok &= check_band("analyze_source(mriq) wall (s)", sa.median_s, 0.0, 1.0);

    println!("\n--- json ---");
    let doc = Json::obj(vec![
        ("bench", Json::str("canalyze_pgo")),
        ("series", Json::arr(series)),
        ("analyze_mriq_wall_s", Json::num(sa.median_s)),
        ("mriq_speedup", Json::num(mriq_speedup)),
        ("dispatched_ops_mriq", Json::num(ops.total() as f64)),
    ]);
    println!("{}", doc.to_string_pretty());

    if enforce {
        assert!(ok, "canalyze_pgo ceilings violated — see BENCH_canalyze.json");
    } else if !ok {
        println!("(ceilings missed — informational; set CANALYZE_PGO_ASSERT=1 to enforce)");
    }
}
