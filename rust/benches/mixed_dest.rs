//! Bench: mixed-destination search (DESIGN.md §15) vs the classic
//! single-destination flows.
//!
//! For each workload the bench runs the three single-destination searches
//! (GPU GA, many-core GA, FPGA narrowing funnel) and the per-gene
//! mixed-destination search over the full `{host, GPU, FPGA, many-core}`
//! alphabet, recording for each flow:
//!
//! * search wall time (the cost of the 4x-wider plan space);
//! * front quality — the minimum W·s over the flow's Pareto front and the
//!   front size.
//!
//! Environment knobs (see BENCH_mixed.json):
//!
//! * `MIXED_ASSERT=1` — enforce the front-quality contract: on at least
//!   one of the benched workloads the mixed front must contain a plan
//!   with strictly lower W·s than every plan any single-destination flow
//!   measured. CI sets this; the wall-time series is always reported,
//!   never asserted (machine dependent).
//!
//! Emits a final JSON object on stdout for the perf dashboard.

use enadapt::canalyze::analyze_source;
use enadapt::devices::DeviceKind;
use enadapt::offload::{fpga_flow, gpu_flow, mixed_dest, FpgaFlowConfig, GpuFlowConfig, MixedDestSpec};
use enadapt::search::GaConfig;
use enadapt::util::benchkit::section;
use enadapt::util::json::Json;
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;
use std::time::Instant;

const SEED: u64 = 42;
const TARGET_CPU_S: f64 = 14.0;

/// One searched flow, reduced to the comparison axes.
struct FlowPoint {
    label: String,
    wall_s: f64,
    front_min_ws: f64,
    front_len: usize,
    trials: usize,
}

fn front_min_ws(points: &[enadapt::search::Scored]) -> f64 {
    points
        .iter()
        .map(|s| s.objectives.energy_ws)
        .fold(f64::INFINITY, f64::min)
}

fn ga_cfg() -> GpuFlowConfig {
    GpuFlowConfig {
        ga: GaConfig {
            population: 16,
            generations: 12,
            ..GaConfig::default()
        },
        seed: SEED,
        ..GpuFlowConfig::default()
    }
}

fn single_flows(app: &AppModel) -> Vec<FlowPoint> {
    let mut flows = Vec::new();
    for device in [DeviceKind::Gpu, DeviceKind::ManyCore] {
        let env = VerifEnvConfig::r740_pac().build(SEED);
        let start = Instant::now();
        let out = gpu_flow::run_on(app, &env, &ga_cfg(), device).expect("single-dest flow");
        flows.push(FlowPoint {
            label: device.name().to_string(),
            wall_s: start.elapsed().as_secs_f64(),
            front_min_ws: front_min_ws(&out.search.front.points),
            front_len: out.search.front.points.len(),
            trials: out.trials,
        });
    }
    let env = VerifEnvConfig::r740_pac().build(SEED);
    let start = Instant::now();
    let out = fpga_flow::run(app, &env, &FpgaFlowConfig::default()).expect("fpga funnel");
    flows.push(FlowPoint {
        label: "fpga".into(),
        wall_s: start.elapsed().as_secs_f64(),
        front_min_ws: front_min_ws(&out.front.points),
        front_len: out.front.points.len(),
        trials: out.funnel.first_round + out.funnel.second_round + out.funnel.block_round,
    });
    flows
}

fn mixed_flow(app: &AppModel) -> (FlowPoint, usize) {
    let env = VerifEnvConfig::r740_pac().build(SEED);
    let start = Instant::now();
    let out =
        mixed_dest::run(app, &env, &ga_cfg(), &MixedDestSpec::default()).expect("mixed-dest flow");
    (
        FlowPoint {
            label: "mixed".into(),
            wall_s: start.elapsed().as_secs_f64(),
            front_min_ws: front_min_ws(&out.search.front.points),
            front_len: out.search.front.points.len(),
            trials: out.trials,
        },
        out.refine_trials,
    )
}

fn main() {
    let enforce = std::env::var("MIXED_ASSERT").as_deref() == Ok("1");

    println!("=== mixed_dest: per-gene destination search vs single-destination flows ===\n");

    let mut series = Vec::new();
    let mut any_dominates = false;
    for (name, src) in [("mriq", workloads::MRIQ_C), ("gemm", workloads::GEMM_C)] {
        let an = analyze_source(&format!("{name}.c"), src).expect("analyze");
        let env_cfg = VerifEnvConfig::r740_pac();
        let app =
            AppModel::from_analysis(&an, &env_cfg.cpu, TARGET_CPU_S).expect("app model");

        section(&format!(
            "{name}: {} plan genes — 2^{} single-destination plans vs 4^{} mixed plans",
            app.genome_len(),
            app.genome_len(),
            app.genome_len()
        ));
        let singles = single_flows(&app);
        let (mixed, refine_trials) = mixed_flow(&app);

        let single_best_ws = singles
            .iter()
            .map(|f| f.front_min_ws)
            .fold(f64::INFINITY, f64::min);
        let dominates = mixed.front_min_ws < single_best_ws;
        any_dominates |= dominates;

        let mut table = Table::new(&[
            "flow",
            "wall [s]",
            "trials",
            "front",
            "front min [W*s]",
        ]);
        for f in singles.iter().chain(std::iter::once(&mixed)) {
            table.row(&[
                f.label.clone(),
                format!("{:.3}", f.wall_s),
                f.trials.to_string(),
                f.front_len.to_string(),
                format!("{:.0}", f.front_min_ws),
            ]);
        }
        println!("{}", table.render());
        println!(
            "{name}: mixed front min {:.0} W·s vs best single-destination {:.0} W·s — {} \
             ({refine_trials} refinement trials)\n",
            mixed.front_min_ws,
            single_best_ws,
            if dominates {
                "mixed plan strictly dominates"
            } else {
                "no strict mixed win"
            }
        );

        let flow_json = |f: &FlowPoint| {
            Json::obj(vec![
                ("flow", Json::str(f.label.as_str())),
                ("wall_s", Json::num(f.wall_s)),
                ("trials", Json::num(f.trials as f64)),
                ("front_len", Json::num(f.front_len as f64)),
                ("front_min_ws", Json::num(f.front_min_ws)),
            ])
        };
        series.push(Json::obj(vec![
            ("workload", Json::str(name)),
            ("genes", Json::num(app.genome_len() as f64)),
            (
                "flows",
                Json::arr(
                    singles
                        .iter()
                        .chain(std::iter::once(&mixed))
                        .map(flow_json)
                        .collect(),
                ),
            ),
            ("single_best_ws", Json::num(single_best_ws)),
            ("mixed_min_ws", Json::num(mixed.front_min_ws)),
            ("mixed_dominates", Json::Bool(dominates)),
        ]));
    }

    if enforce {
        assert!(
            any_dominates,
            "no benched workload produced a mixed front plan with strictly lower W·s \
             than the best single-destination plan — under the BENCH_mixed.json contract"
        );
        println!("ok: a mixed plan strictly dominates the best single-destination plan on W·s");
    } else {
        println!("(MIXED_ASSERT unset: front-quality contract reported, not enforced)");
    }

    section("machine-readable result");
    println!(
        "{}",
        Json::obj(vec![
            ("bench", Json::str("mixed_dest")),
            ("seed", Json::num(SEED as f64)),
            ("series", Json::arr(series)),
            ("any_mixed_dominates", Json::Bool(any_dominates)),
        ])
        .to_string_pretty()
    );
}
