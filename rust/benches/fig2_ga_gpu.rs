//! Bench: **Fig. 2** — "Automatic GPU offload method considering power
//! consumption".
//!
//! Fig. 2 is the GA flow diagram; the quantitative content it implies is
//! the search behaviour, regenerated here:
//!
//! * convergence series (best evaluation value per generation);
//! * the power-aware vs time-only ablation (what this paper adds to the
//!   previous method (33));
//! * the transfer-consolidation ablation (§3.1's second contribution);
//! * the timeout-penalty rule (§4.1b: >3 min ⇒ t := 1000 s);
//! * GA engine throughput (synthetic fitness — pure engine cost).

use enadapt::canalyze::analyze_source;
use enadapt::offload::{gpu_flow, GpuFlowConfig};
use enadapt::search::{run_synthetic, FitnessSpec, GaConfig, GaStrategy};
use enadapt::util::benchkit::{bench, check_band, section};
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn main() {
    println!("=== fig2_ga_gpu: GA-driven GPU offload with power-aware fitness ===");

    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();
    let ga_cfg = GaConfig {
        population: 16,
        generations: 20,
        ..Default::default()
    };

    section("convergence (best evaluation value per generation)");
    let env = VerifEnvConfig::r740_pac().build(42);
    let out = gpu_flow::run(
        &app,
        &env,
        &GpuFlowConfig {
            ga: ga_cfg,
            seed: 42,
            ..Default::default()
        },
    )
    .expect("ga flow");
    println!("generation, best_value, mean_value, patterns_measured");
    for h in &out.search.history {
        println!(
            "{:>4}, {:.6}, {:.6}, {}",
            h.generation, h.best, h.mean, h.measured
        );
    }
    println!(
        "\nbest pattern {} → {:.2} s, {:.1} W, {:.0} W·s (baseline {:.2} s, {:.0} W·s)",
        out.best.pattern,
        out.best.measurement.time_s,
        out.best.measurement.mean_w,
        out.best.measurement.energy_ws,
        out.baseline.time_s,
        out.baseline.energy_ws
    );

    section("ablation: fitness & transfer mode");
    let mut t = Table::new(&[
        "variant",
        "best time [s]",
        "best power [W]",
        "best energy [W*s]",
        "trials",
    ]);
    let mut results = Vec::new();
    for (label, fitness, transfer_opt) in [
        ("power-aware + batched (paper)", FitnessSpec::paper(), true),
        ("time-only + batched (previous method)", FitnessSpec::time_only(), true),
        ("power-aware + per-entry (no §3.1 batching)", FitnessSpec::paper(), false),
    ] {
        let env = VerifEnvConfig::r740_pac().build(42);
        let out = gpu_flow::run(
            &app,
            &env,
            &GpuFlowConfig {
                ga: ga_cfg,
                fitness,
                seed: 42,
                transfer_opt,
                parallel_trials: false,
                ..Default::default()
            },
        )
        .expect("ga flow");
        t.row(&[
            label.to_string(),
            format!("{:.2}", out.best.measurement.time_s),
            format!("{:.1}", out.best.measurement.mean_w),
            format!("{:.0}", out.best.measurement.energy_ws),
            out.trials.to_string(),
        ]);
        results.push((label, out));
    }
    println!("{}", t.render());

    let paper = &results[0].1;
    let time_only = &results[1].1;
    let no_batch = &results[2].1;
    let mut ok = true;
    ok &= check_band(
        "power-aware energy ≤ time-only energy (W·s ratio)",
        time_only.best.measurement.energy_ws / paper.best.measurement.energy_ws,
        0.95,
        10.0,
    );
    // The GA can *sidestep* per-entry costs by preferring entries=1
    // patterns, so compare the best values loosely…
    ok &= check_band(
        "batched ≥ per-entry value ratio (GA-level)",
        paper.best.value / no_batch.best.value,
        0.99,
        10.0,
    );
    // …and demonstrate the §3.1 batching win on a *fixed* many-entry
    // pattern (offloading the inner k-loop: one launch per voxel).
    {
        use enadapt::devices::TransferMode;
        let outer = app
            .loops
            .iter()
            .max_by(|x, y| x.cpu_time_s.partial_cmp(&y.cpu_time_s).unwrap())
            .unwrap()
            .id;
        let inner = app.loops.iter().find(|l| l.parent == Some(outer)).unwrap().id;
        let pos = app.candidates.iter().position(|&c| c == inner).unwrap();
        let mut inner_bits = vec![false; app.genome_len()];
        inner_bits[pos] = true;
        let env2 = VerifEnvConfig::r740_pac().build(42);
        let naive = env2.measure(&app, &inner_bits, enadapt::devices::DeviceKind::Gpu, TransferMode::PerEntry);
        let batched = env2.measure(&app, &inner_bits, enadapt::devices::DeviceKind::Gpu, TransferMode::Batched);
        println!(
            "  fixed inner-loop pattern: per-entry {:.2} s vs batched {:.2} s",
            naive.time_s, batched.time_s
        );
        ok &= check_band(
            "§3.1 batching speedup on inner-loop pattern",
            naive.time_s / batched.time_s,
            1.1,
            1000.0,
        );
    }
    ok &= check_band(
        "GA improves on baseline (value ratio)",
        paper.best.value / paper.baseline_value,
        1.5,
        50.0,
    );

    section("timeout-penalty rule (§4.1b)");
    let f = FitnessSpec::paper();
    println!(
        "  clean 150 s trial value:    {:.6}",
        f.value(150.0, 120.0, false)
    );
    println!(
        "  timed-out trial value:      {:.6}  (time := 1000 s)",
        f.value(150.0, 120.0, true)
    );
    ok &= check_band(
        "timeout penalty ratio",
        f.value(150.0, 120.0, false) / f.value(150.0, 120.0, true),
        2.0,
        3.5,
    );

    section("GA engine throughput (synthetic fitness)");
    println!(
        "{}",
        bench("ga strategy 16x20 onemax(len=16)", 2, 20, || {
            let r =
                run_synthetic(&GaStrategy { cfg: ga_cfg }, 16, 7, |g| g.ones() as f64).unwrap();
            std::hint::black_box(r.best_value);
        })
        .row()
    );

    println!(
        "\nfig2_ga_gpu: {}",
        if ok { "ALL BANDS PASS" } else { "SOME BANDS FAILED" }
    );
}
