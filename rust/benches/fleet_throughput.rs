//! Bench: fleet-coordinator throughput — jobs/sec and shared-cache hit
//! rate for the 4-workload × 3-destination matrix at pool sizes 1/2/4/8.
//!
//! This is the perf trajectory of the PR that turned the serial
//! one-job-at-a-time coordinator into a concurrent fleet with a shared
//! cross-job measurement cache: wall-clock should drop roughly with the
//! worker count (until the machine runs out of cores) while the per-job
//! results stay bit-identical to the serial path (see `tests/fleet.rs`).
//!
//! Emits a final JSON object on stdout for the perf dashboard.

use enadapt::coordinator::{fleet, run_fleet, Destination, FleetConfig, FleetSpec, JobConfig};
use enadapt::search::GaConfig;
use enadapt::offload::GpuFlowConfig;
use enadapt::util::benchkit::section;
use enadapt::util::json::Json;
use enadapt::util::tablefmt::Table;

fn template() -> JobConfig {
    JobConfig {
        ga_flow: GpuFlowConfig {
            ga: GaConfig {
                population: 8,
                generations: 6,
                ..Default::default()
            },
            parallel_trials: false,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// 4 workloads × {gpu, fpga, manycore} (mixed excluded: it is itself a
/// three-destination sweep and would skew the per-job numbers).
fn matrix() -> Vec<FleetSpec> {
    fleet::full_matrix()
        .into_iter()
        .filter(|s| !matches!(s.destination, Destination::Mixed))
        .collect()
}

fn main() {
    println!("=== fleet_throughput: concurrent offload matrix, shared measurement cache ===");
    let specs = matrix();
    println!(
        "matrix: {} jobs ({} workloads x 3 destinations)\n",
        specs.len(),
        specs.len() / 3
    );

    section("pool-size sweep");
    let mut table = Table::new(&[
        "workers",
        "wall [s]",
        "serial [s]",
        "speedup",
        "jobs/s",
        "cache hits",
        "hit rate",
    ]);
    let mut series = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let cfg = FleetConfig {
            template: template(),
            workers,
            ..Default::default()
        };
        let report = run_fleet(&specs, &cfg).expect("fleet run");
        let failed = report.jobs.iter().filter(|j| j.report.is_err()).count();
        assert_eq!(failed, 0, "all fleet jobs must succeed");
        table.row(&[
            workers.to_string(),
            format!("{:.3}", report.wall_s),
            format!("{:.3}", report.serial_wall_s),
            format!("{:.2}x", report.speedup()),
            format!("{:.2}", report.jobs_per_s()),
            report.cache_hits.to_string(),
            format!("{:.0}%", report.hit_rate() * 100.0),
        ]);
        series.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("jobs", Json::num(report.jobs.len() as f64)),
            ("wall_s", Json::num(report.wall_s)),
            ("serial_wall_s", Json::num(report.serial_wall_s)),
            ("speedup", Json::num(report.speedup())),
            ("jobs_per_s", Json::num(report.jobs_per_s())),
            ("cache_hits", Json::num(report.cache_hits as f64)),
            ("cache_misses", Json::num(report.cache_misses as f64)),
            ("hit_rate", Json::num(report.hit_rate())),
        ]));
    }
    println!("{}", table.render());

    section("machine-readable result");
    println!(
        "{}",
        Json::obj(vec![
            ("bench", Json::str("fleet_throughput")),
            ("matrix_jobs", Json::num(specs.len() as f64)),
            ("series", Json::arr(series)),
        ])
        .to_string_pretty()
    );
}
