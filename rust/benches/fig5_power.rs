//! Bench: **Fig. 5** — "Power consumption with FPGA offloading (MRI-Q)".
//!
//! Regenerates the paper's only quantitative figure: the whole-server
//! power (W) vs time (s) trace for MRI-Q processed CPU-only vs offloaded
//! to the FPGA, plus the headline numbers:
//!
//! | quantity            | paper        | this harness                   |
//! |---------------------|--------------|--------------------------------|
//! | CPU-only time       | 14 s         | band 13–15.5 s                 |
//! | offloaded time      | 2 s          | band 1.2–3.2 s                 |
//! | CPU-only power      | ≈121 W       | band 118–124 W                 |
//! | offloaded power     | ≈111 W       | band 106–117 W                 |
//! | CPU-only energy     | 1,690 W·s    | band 1,500–1,900 W·s           |
//! | offloaded energy    | 223 W·s      | band 150–360 W·s               |
//!
//! Also times the measurement machinery itself (the L3 hot path).

use enadapt::canalyze::analyze_source;
use enadapt::coordinator::{run_job, Destination, JobConfig};
use enadapt::devices::DeviceKind;
use enadapt::util::benchkit::{bench, check_band, section};
use enadapt::util::tablefmt::{ascii_plot, Table};
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn main() {
    println!("=== fig5_power: MRI-Q power consumption with FPGA offloading ===");

    // Full Steps 1-7 job, exactly as the paper ran the experiment.
    let cfg = JobConfig {
        destination: Destination::Device(DeviceKind::Fpga),
        seed: 42,
        ..Default::default()
    };
    let job = run_job("mriq.c", workloads::MRIQ_C, &cfg).expect("job");

    section("power trace (paper Fig. 5)");
    let base_pts = job.baseline.trace.points();
    let off_pts = job.production.trace.points();
    println!(
        "{}",
        ascii_plot(&[("cpu-only", &base_pts), ("fpga offload", &off_pts)], 70, 16)
    );
    // The raw series, like the figure's data points.
    println!("cpu-only samples (t, W):   {:?}", compact(&base_pts));
    println!("offloaded samples (t, W):  {:?}", compact(&off_pts));

    section("headline numbers vs paper");
    let mut t = Table::new(&["quantity", "paper", "measured"]);
    let b = &job.baseline;
    let o = &job.production;
    t.row(&["CPU-only time [s]".into(), "14".into(), format!("{:.2}", b.time_s)]);
    t.row(&["offloaded time [s]".into(), "2".into(), format!("{:.2}", o.time_s)]);
    t.row(&["CPU-only power [W]".into(), "121".into(), format!("{:.1}", b.mean_w)]);
    t.row(&["offloaded power [W]".into(), "111".into(), format!("{:.1}", o.mean_w)]);
    t.row(&["CPU-only energy [W*s]".into(), "1690".into(), format!("{:.0}", b.energy_ws)]);
    t.row(&["offloaded energy [W*s]".into(), "223".into(), format!("{:.0}", o.energy_ws)]);
    t.row(&[
        "speedup".into(),
        "7.0x".into(),
        format!("{:.1}x", b.time_s / o.time_s),
    ]);
    t.row(&[
        "energy reduction".into(),
        "7.6x".into(),
        format!("{:.1}x", b.energy_ws / o.energy_ws),
    ]);
    println!("{}", t.render());

    let mut ok = true;
    ok &= check_band("cpu-only time [s]", b.time_s, 13.0, 15.5);
    ok &= check_band("offloaded time [s]", o.time_s, 1.2, 3.2);
    ok &= check_band("cpu-only power [W]", b.mean_w, 118.0, 124.0);
    ok &= check_band("offloaded power [W]", o.mean_w, 106.0, 117.0);
    ok &= check_band("cpu-only energy [W*s]", b.energy_ws, 1500.0, 1900.0);
    ok &= check_band("offloaded energy [W*s]", o.energy_ws, 150.0, 360.0);
    ok &= check_band("speedup", b.time_s / o.time_s, 4.0, 12.0);
    ok &= check_band("energy ratio", b.energy_ws / o.energy_ws, 4.0, 12.0);

    section("measurement-machinery wall time (L3 hot path)");
    let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0).unwrap();
    let env = VerifEnvConfig::r740_pac().build(1);
    let bits = job.best.pattern.bits().to_vec();
    println!(
        "{}",
        bench("verifier.measure(fpga pattern)", 3, 50, || {
            let m = env.measure(&app, &bits, DeviceKind::Fpga, Default::default());
            std::hint::black_box(m.energy_ws);
        })
        .row()
    );
    println!(
        "{}",
        bench("verifier.measure(cpu-only)", 3, 50, || {
            let m = env.measure_cpu_only(&app);
            std::hint::black_box(m.energy_ws);
        })
        .row()
    );
    println!(
        "{}",
        bench("analyze_source(mriq.c) [steps 1-2]", 1, 10, || {
            let an = analyze_source("mriq.c", workloads::MRIQ_C).unwrap();
            std::hint::black_box(an.n_loops());
        })
        .row()
    );

    println!(
        "\nfig5_power: {}",
        if ok { "ALL BANDS PASS" } else { "SOME BANDS FAILED" }
    );
}

/// First+middle+last points, to keep stdout readable.
fn compact(pts: &[(f64, f64)]) -> Vec<(f64, f64)> {
    if pts.len() <= 6 {
        return pts.to_vec();
    }
    let mut v = pts[..3].to_vec();
    v.push(pts[pts.len() / 2]);
    v.extend_from_slice(&pts[pts.len() - 2..]);
    v
}
