//! Bench: **power_meters** — cross-sensor validation of the meter layer.
//!
//! Runs the Fig. 5 MRI-Q measurements (CPU-only and best-FPGA-pattern)
//! under every meter backend (1 Hz IPMI, high-rate RAPL-style, exact
//! oracle) and checks:
//!
//! * every backend lands in the DESIGN.md §1 bands (which are asserted
//!   under the IPMI meter by the unit tests);
//! * per-component W·s sum to the whole-server total within 1e-6;
//! * backends agree with the oracle within sampling tolerance;
//! * the measurement hot path cost per backend (samples/s scale with the
//!   meter rate, so RAPL is the expensive one).

use enadapt::canalyze::analyze_source;
use enadapt::devices::{DeviceKind, TransferMode};
use enadapt::power::{Component, MeterConfig};
use enadapt::util::benchkit::{bench, check_band, section};
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn main() {
    println!("=== power_meters: sensor backends on the Fig. 5 measurements ===");

    let an = analyze_source("mriq.c", workloads::MRIQ_C).expect("analyze");
    let base_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &base_cfg.cpu, 14.0).expect("app model");
    let best_bits = {
        // The dominant computeQ nest — the Fig. 5 winning pattern.
        let outer = app
            .loops
            .iter()
            .max_by(|a, b| a.cpu_time_s.partial_cmp(&b.cpu_time_s).unwrap())
            .unwrap()
            .id;
        let pos = app.candidates.iter().position(|&c| c == outer).unwrap();
        let mut bits = vec![false; app.genome_len()];
        bits[pos] = true;
        bits
    };

    let meters = [
        MeterConfig::from_name("ipmi").unwrap(),
        MeterConfig::from_name("rapl").unwrap(),
        MeterConfig::Oracle,
    ];

    section("per-meter Fig. 5 numbers + component attribution");
    let mut t = Table::new(&[
        "meter", "run", "time [s]", "mean [W]", "peak [W]", "energy [W*s]", "idle", "host",
        "accel", "xfer",
    ]);
    let mut ok = true;
    let mut oracle_cpu = 0.0;
    let mut oracle_fpga = 0.0;
    for m in meters {
        let mut cfg = VerifEnvConfig::r740_pac();
        cfg.meter = m;
        let env = cfg.build(42);
        let cpu = env.measure_cpu_only(&app);
        let fpga = env.measure(&app, &best_bits, DeviceKind::Fpga, TransferMode::Batched);
        if let MeterConfig::Oracle = m {
            oracle_cpu = cpu.energy_ws;
            oracle_fpga = fpga.energy_ws;
        }
        for (label, meas) in [("cpu-only", &cpu), ("fpga", &fpga)] {
            let c = &meas.report.components;
            t.row(&[
                m.name().to_string(),
                label.to_string(),
                format!("{:.2}", meas.time_s),
                format!("{:.1}", meas.mean_w),
                format!("{:.1}", meas.report.peak_w),
                format!("{:.0}", meas.energy_ws),
                format!("{:.0}", c.idle_ws),
                format!("{:.0}", c.host_cpu_ws),
                format!("{:.1}", c.accelerator_ws),
                format!("{:.1}", c.transfer_ws),
            ]);
            let sum = c.total_ws();
            if (sum - meas.energy_ws).abs() > 1e-6 * meas.energy_ws.max(1.0) {
                println!(
                    "FAIL [{} {label}] components sum {} != total {}",
                    m.name(),
                    sum,
                    meas.energy_ws
                );
                ok = false;
            }
        }
        ok &= check_band(
            &format!("{} cpu-only energy [W*s]", m.name()),
            cpu.energy_ws,
            1500.0,
            1900.0,
        );
        ok &= check_band(
            &format!("{} offloaded energy [W*s]", m.name()),
            fpga.energy_ws,
            150.0,
            360.0,
        );
        ok &= check_band(
            &format!("{} energy ratio", m.name()),
            cpu.energy_ws / fpga.energy_ws,
            4.0,
            12.0,
        );
    }
    println!("{}", t.render());

    section("cross-sensor agreement vs oracle");
    for m in meters {
        let mut cfg = VerifEnvConfig::r740_pac();
        cfg.meter = m;
        let env = cfg.build(42);
        let cpu = env.measure_cpu_only(&app);
        let fpga = env.measure(&app, &best_bits, DeviceKind::Fpga, TransferMode::Batched);
        // The short (~2 s) offloaded trace leaves 1 Hz IPMI only a few
        // samples, so its tolerance is wider than the 14 s baseline's.
        ok &= check_band(
            &format!("{} / oracle (cpu-only)", m.name()),
            cpu.energy_ws / oracle_cpu,
            0.95,
            1.05,
        );
        ok &= check_band(
            &format!("{} / oracle (fpga)", m.name()),
            fpga.energy_ws / oracle_fpga,
            0.80,
            1.20,
        );
    }

    section("measurement hot path per backend");
    for m in meters {
        let mut cfg = VerifEnvConfig::r740_pac();
        cfg.meter = m;
        let env = cfg.build(7);
        println!(
            "{}",
            bench(&format!("measure(cpu-only) [{}]", m.name()), 3, 30, || {
                let meas = env.measure_cpu_only(&app);
                std::hint::black_box(meas.energy_ws);
            })
            .row()
        );
    }

    // Component coverage sanity: the FPGA run exercises all four
    // components under the attributing meters.
    let mut cfg = VerifEnvConfig::r740_pac();
    cfg.meter = MeterConfig::Oracle;
    let env = cfg.build(42);
    let fpga = env.measure(&app, &best_bits, DeviceKind::Fpga, TransferMode::Batched);
    for c in Component::ALL {
        if fpga.report.components.get(c) <= 0.0 {
            println!("FAIL component {} has no energy in the FPGA run", c.name());
            ok = false;
        }
    }

    println!(
        "\npower_meters: {}",
        if ok { "ALL BANDS PASS" } else { "SOME BANDS FAILED" }
    );
    if !ok {
        std::process::exit(1);
    }
}
