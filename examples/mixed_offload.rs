//! §3.3 demo: automatic offload-destination selection in a mixed
//! many-core / GPU / FPGA environment, with early stop on user
//! requirements — and the power-aware twist: the GPU is *faster* on MRI-Q,
//! but the FPGA wins the paper's `t^(-1/2)·p^(-1/2)` evaluation value.
//!
//! ```sh
//! cargo run --release --example mixed_offload
//! ```

use enadapt::canalyze::analyze_source;
use enadapt::offload::{mixed, GpuFlowConfig, MixedConfig, Requirements};
use enadapt::search::{FitnessSpec, GaConfig};
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn main() -> enadapt::Result<()> {
    let an = analyze_source("mriq.c", workloads::MRIQ_C)?;
    let env_cfg = VerifEnvConfig::r740_pac();
    let app = AppModel::from_analysis(&an, &env_cfg.cpu, 14.0)?;

    let ga = GpuFlowConfig {
        ga: GaConfig {
            population: 10,
            generations: 8,
            ..Default::default()
        },
        ..Default::default()
    };

    // --- Scenario A: lenient requirements → early stop saves the FPGA
    //     compile hours.
    println!("=== Scenario A: lenient requirements (3x speedup, 1.5x energy) ===\n");
    let env = VerifEnvConfig::r740_pac().build(7);
    let out = mixed::run(
        &app,
        &env,
        &MixedConfig {
            requirements: Requirements {
                min_speedup: 3.0,
                min_energy_ratio: 1.5,
            },
            ga_flow: ga,
            ..Default::default()
        },
    )?;
    print_outcome(&out);

    // --- Scenario B: impossible requirements → all three verified, the
    //     power-aware value picks the destination.
    println!("\n=== Scenario B: exhaustive verification (no early stop) ===\n");
    let env = VerifEnvConfig::r740_pac().build(7);
    let out_full = mixed::run(
        &app,
        &env,
        &MixedConfig {
            requirements: Requirements {
                min_speedup: f64::INFINITY,
                min_energy_ratio: f64::INFINITY,
            },
            ga_flow: ga,
            ..Default::default()
        },
    )?;
    print_outcome(&out_full);

    // --- Scenario C: same, but with the previous papers' time-only value.
    println!("\n=== Scenario C: ablation — time-only selection (previous method) ===\n");
    let env = VerifEnvConfig::r740_pac().build(7);
    let mut cfg_time = MixedConfig {
        requirements: Requirements {
            min_speedup: f64::INFINITY,
            min_energy_ratio: f64::INFINITY,
        },
        fitness: FitnessSpec::time_only(),
        ga_flow: ga,
        ..Default::default()
    };
    cfg_time.ga_flow.fitness = FitnessSpec::time_only();
    cfg_time.fpga_flow.fitness = FitnessSpec::time_only();
    let out_time = mixed::run(&app, &env, &cfg_time)?;
    print_outcome(&out_time);

    println!(
        "\npower-aware choice: {}   time-only choice: {}",
        out_full.chosen.device, out_time.chosen.device
    );
    if out_full.chosen.device != out_time.chosen.device {
        println!(
            "→ including power in the evaluation value CHANGES the selected \
             destination (the paper's §3.3 point)."
        );
    }
    Ok(())
}

fn print_outcome(out: &mixed::MixedOutcome) {
    let mut t = Table::new(&[
        "destination",
        "best pattern",
        "time [s]",
        "power [W]",
        "energy [W*s]",
        "value",
        "trials",
        "search cost",
    ]);
    for d in &out.tried {
        t.row(&[
            d.device.to_string(),
            d.best.pattern.genome.to_string(),
            format!("{:.2}", d.best.measurement.time_s),
            format!("{:.1}", d.best.measurement.mean_w),
            format!("{:.0}", d.best.measurement.energy_ws),
            format!("{:.5}", d.best.value),
            d.trials.to_string(),
            format!("{:.1} h", d.search_cost_s / 3600.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "baseline: {:.2} s / {:.0} W·s   chosen: {}   early-stopped: {}   skipped: [{}]",
        out.baseline.time_s,
        out.baseline.energy_ws,
        out.chosen.device,
        out.early_stopped,
        out.skipped
            .iter()
            .map(|d| d.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
}
