//! §3.1 demo: the GA search for GPU offload patterns, with the two
//! ablations the paper's method adds over naive directive insertion:
//!
//! * power-aware fitness `t^(-1/2)·p^(-1/2)` vs time-only;
//! * batched CPU↔GPU variable transfers vs per-entry transfers.
//!
//! ```sh
//! cargo run --release --example ga_gpu_search
//! ```

use enadapt::canalyze::analyze_source;
use enadapt::offload::{gpu_flow, GpuFlowConfig};
use enadapt::search::{FitnessSpec, GaConfig};
use enadapt::util::tablefmt::Table;
use enadapt::verifier::{AppModel, VerifEnvConfig};
use enadapt::workloads;

fn main() -> enadapt::Result<()> {
    for (name, src, baseline_s) in [
        ("mriq.c", workloads::MRIQ_C, 14.0),
        ("stencil.c", workloads::STENCIL_C, 4.0),
    ] {
        println!("================================================================");
        println!("== GA GPU search on {name}");
        println!("================================================================\n");
        let an = analyze_source(name, src)?;
        let env_cfg = VerifEnvConfig::r740_pac();
        let app = AppModel::from_analysis(&an, &env_cfg.cpu, baseline_s)?;

        let base_ga = GaConfig {
            population: 12,
            generations: 10,
            ..Default::default()
        };

        let mut t = Table::new(&[
            "variant",
            "best pattern",
            "time [s]",
            "power [W]",
            "energy [W*s]",
            "value",
            "measured",
        ]);
        for (label, fitness, transfer_opt) in [
            ("power-aware + batched (paper)", FitnessSpec::paper(), true),
            ("time-only + batched", FitnessSpec::time_only(), true),
            ("power-aware + per-entry", FitnessSpec::paper(), false),
        ] {
            let env = VerifEnvConfig::r740_pac().build(11);
            let cfg = GpuFlowConfig {
                ga: base_ga,
                fitness,
                seed: 2024,
                transfer_opt,
                parallel_trials: false,
                ..Default::default()
            };
            let out = gpu_flow::run(&app, &env, &cfg)?;
            t.row(&[
                label.to_string(),
                out.best.pattern.genome.to_string(),
                format!("{:.2}", out.best.measurement.time_s),
                format!("{:.1}", out.best.measurement.mean_w),
                format!("{:.0}", out.best.measurement.energy_ws),
                format!("{:.5}", out.best.value),
                out.trials.to_string(),
            ]);

            if label.starts_with("power-aware + batched") {
                println!("convergence (best evaluation value per generation):");
                for h in &out.search.history {
                    let bars = (h.best * 4000.0).min(60.0) as usize;
                    println!(
                        "  gen {:>2}  {:.5}  |{}",
                        h.generation,
                        h.best,
                        "#".repeat(bars)
                    );
                }
                println!();
            }
        }
        println!("{}", t.render());
    }
    Ok(())
}
