//! Function-block offloading end to end: detect the naive matmul in
//! `gemm.c`, search the combined loop + block plan space, and compare
//! the chosen plan against the loop-only search.
//!
//! ```sh
//! cargo run --release --example block_offload
//! ```

use enadapt::coordinator::{report, run_job, Destination, JobConfig};
use enadapt::devices::DeviceKind;
use enadapt::funcblock::{detect, BlockDb};
use enadapt::search::SearchStrategy;
use enadapt::workloads;

fn main() -> enadapt::Result<()> {
    let name = "gemm.c";
    let src = workloads::GEMM_C;

    // What does the block detector see?
    let an = enadapt::canalyze::analyze_source(name, src)?;
    let db = BlockDb::standard();
    let found = detect(&an, &db);
    println!("== detected function blocks in {name} ==");
    for b in &found {
        let impls: Vec<&str> = [DeviceKind::Gpu, DeviceKind::Fpga, DeviceKind::ManyCore]
            .into_iter()
            .filter_map(|d| db.entry(b.kind).and_then(|e| e.impl_for(d)).map(|i| i.library))
            .collect();
        println!(
            "  {} in {}() line {} via {} — covers {} loop(s), impls: {}",
            b.kind,
            b.func,
            b.line,
            b.via.name(),
            b.covered.len(),
            impls.join(", ")
        );
    }
    println!();

    // Exhaust the plan space twice: loop-only vs block-bearing.
    let mk = |blocks| JobConfig {
        destination: Destination::Device(DeviceKind::Gpu),
        blocks,
        ga_flow: enadapt::offload::GpuFlowConfig {
            strategy: SearchStrategy::Exhaustive { max_bits: 12 },
            ..Default::default()
        },
        ..Default::default()
    };
    let loop_only = run_job(name, src, &mk(false))?;
    let blocked = run_job(name, src, &mk(true))?;

    println!("== loop-only search ==\n{}", report::render_job(&loop_only));
    println!("== block-bearing search ==\n{}", report::render_job(&blocked));
    println!(
        "loop-only best : {:>7.0} W·s in {:.2} s ({})",
        loop_only.production.energy_ws,
        loop_only.production.time_s,
        loop_only.best.pattern
    );
    println!(
        "block best     : {:>7.0} W·s in {:.2} s ({})",
        blocked.production.energy_ws,
        blocked.production.time_s,
        blocked.best.pattern
    );
    println!(
        "block substitution saves {:.1}x W·s over the best loop-only plan",
        loop_only.production.energy_ws / blocked.production.energy_ws.max(1e-9)
    );
    Ok(())
}
