//! Quickstart: analyze a program, search for a power-aware offload
//! pattern, and print what the environment-adaptive flow decided.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the bundled `vecadd.c` (transfer-dominated — the search should
//! usually conclude the CPU wins) and `mriq.c` (compute-dense — offload
//! wins big), showing both sides of the decision landscape.

use enadapt::coordinator::{report, run_job, Destination, JobConfig};
use enadapt::devices::DeviceKind;
use enadapt::workloads;

fn main() -> enadapt::Result<()> {
    for (name, src) in [("vecadd.c", workloads::VECADD_C), ("mriq.c", workloads::MRIQ_C)] {
        println!("================================================================");
        println!("== {name}");
        println!("================================================================\n");

        // Steps 1-2 on their own: what does the analyzer see?
        let an = enadapt::canalyze::analyze_source(name, src)?;
        println!("{}", report::loop_table(&an));
        println!(
            "{} of {} loop statements are processable\n",
            an.parallelizable_ids().len(),
            an.n_loops()
        );

        // Full job against the GPU (fast GA settings for a demo).
        let mut cfg = JobConfig {
            destination: Destination::Device(DeviceKind::Gpu),
            ..Default::default()
        };
        cfg.ga_flow.ga.population = 10;
        cfg.ga_flow.ga.generations = 8;
        // vecadd's real runtime is milliseconds; give it a proportional
        // baseline instead of MRI-Q's 14 s.
        if name == "vecadd.c" {
            cfg.baseline = enadapt::coordinator::BaselineSource::Fixed(0.5);
        }
        let job = run_job(name, src, &cfg)?;
        println!("{}", report::render_job(&job));
    }
    Ok(())
}
