//! End-to-end driver (EXPERIMENTS.md §E2E): the paper's §4 experiment on a
//! real executed workload.
//!
//! All three layers compose here:
//!   L1/L2 — the MRI-Q Pallas kernels + JAX model were AOT-lowered to
//!           `artifacts/*.hlo.txt` (`make artifacts`);
//!   this driver *executes* both variants via PJRT from Rust, checks their
//!   numerics agree, and calibrates the verification environment's CPU
//!   baseline from the measured wall time;
//!   L3   — the coordinator runs the full Steps 1–7 FPGA offload job on
//!           the MRI-Q C source and reproduces Fig. 5.
//!
//! ```sh
//! make artifacts && cargo run --release --example mriq_fpga_power
//! ```

use enadapt::coordinator::{report, run_job, BaselineSource, Destination, JobConfig};
use enadapt::devices::DeviceKind;
use enadapt::runtime;
use enadapt::util::json::Json;
use enadapt::workloads;

fn main() -> enadapt::Result<()> {
    println!("=== MRI-Q FPGA offload power evaluation (paper §4 / Fig. 5) ===\n");

    // --- Real execution: load the AOT artifacts and run them. -----------
    let arts = runtime::load_artifacts(&runtime::default_dir())?;
    let rt = runtime::HloRuntime::cpu()?;
    println!("[runtime] platform={} devices={}", rt.platform(), rt.device_count());

    let cpu_model = rt.load_artifact(arts.variant("mriq_cpu_small")?)?;
    let off_model = rt.load_artifact(arts.variant("mriq_offload_small")?)?;
    let cpu_out = cpu_model.run_synth()?;
    let off_out = off_model.run_synth()?;

    // Numerics: the Pallas path must match the plain-jnp path.
    let mut max_err = 0f32;
    for (a, b) in cpu_out.outputs.iter().zip(&off_out.outputs) {
        for (x, y) in a.iter().zip(b) {
            max_err = max_err.max((x - y).abs());
        }
    }
    println!(
        "[runtime] executed mriq_cpu_small ({:.2} ms) and mriq_offload_small ({:.2} ms); \
         max |Δ| = {max_err:.2e}",
        cpu_out.wall_s * 1e3,
        off_out.wall_s * 1e3
    );
    assert!(max_err < 1e-2, "pallas vs jnp mismatch");

    // Measured baseline: time the real HLO, scale to the paper's 64^3 x
    // 2048 problem.
    let t = runtime::time_model(&cpu_model, 1, 5)?;
    let full_s = runtime::scale_to_full(t.mean_s, cpu_model.meta.num_k, cpu_model.meta.num_x, 2048, 262_144);
    println!(
        "[runtime] measured CPU wall {:.3} ms @ {}x{} → full-size estimate {:.2} s \
         (paper testbed: 14 s)\n",
        t.mean_s * 1e3,
        cpu_model.meta.num_k,
        cpu_model.meta.num_x,
        full_s
    );

    // --- The offload job, once with the paper baseline, once measured. --
    for (label, baseline) in [
        ("paper-calibrated (14 s)", BaselineSource::Fixed(14.0)),
        (
            "HLO-measured",
            BaselineSource::MeasuredHlo {
                artifact: "mriq_cpu_small".into(),
                full_k: 2048,
                full_x: 262_144,
            },
        ),
    ] {
        println!("----------------------------------------------------------------");
        println!("-- baseline: {label}");
        println!("----------------------------------------------------------------\n");
        let cfg = JobConfig {
            destination: Destination::Device(DeviceKind::Fpga),
            baseline,
            ..Default::default()
        };
        let job = run_job("mriq.c", workloads::MRIQ_C, &cfg)?;
        println!("{}", report::render_job(&job));

        // Persist machine-readable results for EXPERIMENTS.md.
        let out = Json::obj(vec![
            ("baseline_source", Json::str(label)),
            ("report", report::job_json(&job)),
        ]);
        let path = format!(
            "mriq_fpga_power_{}.json",
            if label.starts_with("paper") { "paper" } else { "measured" }
        );
        std::fs::write(&path, out.to_string_pretty())?;
        println!("[saved] {path}\n");
    }
    Ok(())
}
